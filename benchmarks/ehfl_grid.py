"""Shared EHFL sweep powering the Fig. 4 / 5 / 6 benchmarks.

Paper protocol (§V) scaled to this CPU container: the full protocol is
N=100 clients, T=500 epochs, 300 samples; the sweep below keeps every
structural constant (S=30, kappa=20, E_max=kappa+5, k=10 scaled to N,
mu=0.5, Dirichlet alpha grid, p_bc grid) and shrinks N/T/samples.

Every (policy, alpha, p_bc, scenario) cell runs its full multi-seed sweep
through ``repro.core.run_batch`` — the T-epoch simulation, eval included,
vmapped over the seed axis and executed as ONE jitted call (DESIGN.md §8) —
instead of one Python-loop ``run_simulation`` per seed.  Scalar fields of a
cell record ("f1", "avg_age", "energy_per_epoch", "total_energy") are means
across seeds; per-seed trajectories ride along under ``*_per_seed``.

Beyond the paper's homogeneous-Bernoulli energy model, the harvest-scenario
gallery (``repro.core.harvest``: bernoulli / markov / diurnal / hetero) runs
through the same engine via :func:`run_scenarios`.

Results are cached to experiments/ehfl_grid/<tag>.json.

CLI:
  PYTHONPATH=src python benchmarks/ehfl_grid.py --quick            # scenario gallery
  PYTHONPATH=src python benchmarks/ehfl_grid.py --quick --grid     # + full policy grid
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Sequence

import jax
import numpy as np

from repro.configs.cifar_cnn import CNNConfig
from repro.core import SCENARIOS, EHFLConfig, run_batch
from repro.data import make_federated_dataset
from repro.fl import cnn_backend

CACHE = Path(__file__).resolve().parent.parent / "experiments" / "ehfl_grid"

BENCH_CNN = CNNConfig(name="bench", image_size=16, conv_channels=(8, 8, 16, 16, 32, 32), fc_dims=(64, 32))

POLICIES = ("vaoi", "fedavg", "fedbacys", "fedbacys_odd")

# the data partition and backend depend only on (N, samples, alpha, seed) /
# nothing — memoize so scenario/policy cells sharing them don't regenerate
_DATA_CACHE: dict = {}
_BACKEND = None


def _bench_backend():
    global _BACKEND
    if _BACKEND is None:
        _BACKEND = cnn_backend(BENCH_CNN)
    return _BACKEND


def _bench_data(num_clients: int, samples: int, alpha: float, seed: int):
    k = (num_clients, samples, alpha, seed)
    if k not in _DATA_CACHE:
        _DATA_CACHE[k] = make_federated_dataset(
            jax.random.PRNGKey(seed),
            num_clients=num_clients,
            samples_per_client=samples,
            alpha=alpha,
            test_size=300,
            image_size=BENCH_CNN.image_size,
        )
    return _DATA_CACHE[k]


def grid_settings(quick: bool):
    if quick:
        return dict(
            alphas=(0.1, 1.0),
            pbcs=(0.1, 1.0),
            num_clients=16,
            samples=40,
            epochs=30,
            eval_every=6,
            k=4,
            seeds=(0, 1),
        )
    return dict(
        alphas=(0.1, 1.0, 10.0),
        pbcs=(0.01, 0.1, 1.0),
        num_clients=40,
        samples=120,
        epochs=120,
        eval_every=10,
        k=8,
        seeds=(0, 1, 2),
    )


def run_cell(
    policy: str,
    alpha: float,
    p_bc: float,
    st: dict,
    seed: int = 0,
    scenario: str = "bernoulli",
    seeds: Sequence[int] | None = None,
) -> dict:
    """One sweep cell: all ``seeds`` in one batched, jitted call.

    ``seed`` is the base seed (data partition + default sweep offset);
    ``seeds`` defaults to ``st["seeds"]`` shifted by it.
    """
    if seeds is None:
        seeds = tuple(s + seed for s in st.get("seeds", (0,)))
    seeds = tuple(int(s) for s in seeds)
    tag = (  # d<seed> = data-partition seed; s<...> = sweep seeds
        f"{policy}_{scenario}_a{alpha}_p{p_bc}_N{st['num_clients']}_T{st['epochs']}"
        f"_n{st['samples']}_d{seed}_s{'-'.join(map(str, seeds))}"
    )
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / f"{tag}.json"
    if f.exists():
        return json.loads(f.read_text())
    data = _bench_data(st["num_clients"], st["samples"], alpha, seed)
    cfg = EHFLConfig(
        num_clients=st["num_clients"],
        epochs=st["epochs"],
        slots_per_epoch=30,
        kappa=20,
        p_bc=p_bc,
        k=st["k"],
        mu=0.5,
        e_max=25,
        policy=policy,
        alpha=alpha,
        seed=seed,
        eval_every=st["eval_every"],
        probe_size=20,
        harvest=scenario,
    )
    t0 = time.time()
    out = run_batch(cfg, _bench_backend(), data, seeds)
    m = out["metrics"]  # every entry has a leading (len(seeds),) axis
    f1 = np.asarray(m["f1"], np.float64)
    rec = {
        "policy": policy,
        "alpha": alpha,
        "p_bc": p_bc,
        "scenario": scenario,
        "seeds": list(seeds),
        "wall_s": round(time.time() - t0, 1),
        "f1": f1.mean(0).tolist(),
        "f1_std": f1.std(0).tolist(),
        "f1_per_seed": f1.tolist(),
        "f1_epochs": np.asarray(m["f1_epochs"]).tolist(),
        "avg_age": np.asarray(m["avg_age"], np.float64).mean(0).tolist(),
        "energy_per_epoch": np.asarray(m["energy"], np.float64).mean(0).tolist(),
        "total_energy": float(np.asarray(m["total_energy"], np.float64).mean()),
        "total_energy_per_seed": np.asarray(m["total_energy"]).tolist(),
        "n_started": float(np.asarray(m["n_started"]).sum(-1).mean()),
        "n_uploaded": float(np.asarray(m["n_uploaded"]).sum(-1).mean()),
    }
    f.write_text(json.dumps(rec))
    return rec


def run_grid(quick: bool = True, seed: int = 0):
    st = grid_settings(quick)
    cells = {}
    for alpha in st["alphas"]:
        for p_bc in st["pbcs"]:
            for policy in POLICIES:
                cells[(policy, alpha, p_bc)] = run_cell(policy, alpha, p_bc, st, seed)
    return cells, st


def run_scenarios(quick: bool = True, seed: int = 0, policy: str = "vaoi"):
    """Harvest-scenario gallery at the paper's hardest cell (small alpha,
    scarce energy): every scenario, same mean rate, batched seed sweep."""
    st = grid_settings(quick)
    alpha = st["alphas"][0]
    p_bc = st["pbcs"][0] if quick else 0.1  # full grid's 0.01 is ultra-scarce
    cells = {}
    for scenario in SCENARIOS:
        cells[scenario] = run_cell(policy, alpha, p_bc, st, seed, scenario=scenario)
    return cells, st


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CPU-feasible settings")
    ap.add_argument("--grid", action="store_true", help="also run the policy grid")
    ap.add_argument("--policy", default="vaoi", choices=POLICIES)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    quick = args.quick

    cells, st = run_scenarios(quick, args.seed, args.policy)
    print(f"{'scenario':<11} {'final F1':>9} {'f1 std':>8} {'energy':>9} {'wall_s':>7}")
    for scenario, rec in cells.items():
        print(
            f"{scenario:<11} {rec['f1'][-1]:>9.4f} {rec['f1_std'][-1]:>8.4f} "
            f"{rec['total_energy']:>9.0f} {rec['wall_s']:>7.1f}"
        )
    if args.grid:
        grid, _ = run_grid(quick, args.seed)
        for (policy, alpha, p_bc), rec in grid.items():
            print(
                f"grid {policy:<13} a={alpha:<5} p={p_bc:<5} "
                f"f1={rec['f1'][-1]:.4f} energy={rec['total_energy']:.0f}"
            )


if __name__ == "__main__":
    main()
