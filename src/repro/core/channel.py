"""Lossy-uplink channel library — pluggable delivery processes (DESIGN.md §12).

The simulator's communication model was free AND perfectly reliable: every
upload that left a client landed in the FedAvg round.  Real EHFL uplinks are
neither — contention destroys colliding packets (multichannel slotted ALOHA,
arXiv:2309.06033) and fading links black out for whole rounds
(energy-constrained over-the-air scheduling, arXiv:2106.00490).  This module
factors "did the upload land" out of the simulator behind the same tiny
stateful protocol as the harvest and stream libraries (DESIGN.md §7/§10):

  * ``init(key, n) -> state``   — per-simulation channel state;
  * ``step(state, attempting) -> (delivered, state)`` — one epoch:
    ``attempting`` is the (N,) bool mask of clients that transmitted this
    epoch (the energy is already spent — a lost upload refunds nothing);
    ``delivered`` is the (N,) bool subset whose message reached the server.

``persistent`` mirrors the harvest/stream flag: ``ideal`` carries no state
and consumes no PRNG key, which keeps the default configuration
BIT-IDENTICAL to the pre-channel simulator (tested in
``tests/test_channel.py``); the lossy scenarios own a key chain threaded
through ``EpochCarry.channel``.

What happens to a FAILED upload is the simulator's retry state machine, not
the channel's (``simulator.epoch_body``, DESIGN.md §12): the message stays
pending (an old-carrier retransmission next epoch), the client's retry
counter drives capped exponential backoff, its VAoI re-ages by one version
per failure, and after ``max_retries`` failures the message is dropped.

Scenarios:

  ideal    — always-deliver, stateless/keyless (the pre-channel behavior
             and the default).
  erasure  — i.i.d. per-upload loss.  Mean loss rate ``p_loss``; with
             ``concentration`` c > 0 the per-client rates are drawn once
             from Beta(c·p_loss, c·(1−p_loss)) (heterogeneous links, the
             hetero-harvest profile applied to the uplink), else every
             client shares the scalar rate.
  aloha    — M-channel slotted ALOHA contention: each attempting client
             picks one of ``num_channels`` uplink channels uniformly at
             random; a channel carrying exactly one upload delivers it,
             two or more collide and ALL colliding uploads are destroyed
             (da Silva et al., arXiv:2309.06033).
  fading   — Gilbert–Elliott good/bad link state per client: uploads
             deliver while the link is good and are lost in outage
             (bad state).  ``p_bad`` is the stationary bad fraction,
             ``sojourn`` the phase-relaxation timescale (mean bad sojourn
             sojourn/(1−p_bad) epochs) — the markov-harvest machinery
             applied to the link.

Client-sharded forms (``make_sharded_channel``) follow the fleet recipe of
``harvest.make_sharded_process`` (DESIGN.md §9): every random draw keeps its
single-device ``(n_global,)`` shape, computed from the replicated key, and
each shard slices its own window — with explicit uniforms, never
``random.categorical``.  ALOHA is the one scenario whose delivery decision
needs CROSS-shard information (a collision can span shards), so its sharded
step ``psum``s the per-channel contention counts over the fleet axis before
testing each shard's occupancy — bit-identical to the solo counts because
integer scatter-adds are order-free.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

SCENARIOS = ("ideal", "erasure", "aloha", "fading")


class ChannelProcess(NamedTuple):
    """A stateful per-epoch uplink delivery process (see module docstring)."""

    name: str
    persistent: bool  # state survives across epochs (ideal carries none)
    init: Callable[[jax.Array, int], Any]
    step: Callable[[Any, jax.Array], Tuple[jax.Array, Any]]


def _shard_slice(full: jax.Array, _shard, n_loc: int) -> jax.Array:
    """This shard's (N_loc,) window of a globally-shaped (N,) draw.
    ``_shard = (axis_name, n_global)`` under ``shard_map`` (DESIGN.md §9)."""
    axis_name, _ = _shard
    off = jax.lax.axis_index(axis_name) * n_loc
    return jax.lax.dynamic_slice(full, (off,), (n_loc,))


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


def ideal(_shard=None) -> ChannelProcess:
    """Always-deliver: no state, no PRNG consumption — bit-identical to the
    pre-channel simulator (the retry bookkeeping degenerates to no-ops on an
    all-delivered mask)."""

    def init(key: jax.Array, n: int):
        return None

    def step(state, attempting: jax.Array):
        return attempting, None

    return ChannelProcess("ideal", False, init, step)


def erasure(p_loss: float = 0.2, concentration: float = 0.0, _shard=None) -> ChannelProcess:
    """i.i.d. per-upload erasures at mean rate ``p_loss``; ``concentration``
    c > 0 draws static per-client rates from Beta(c·p, c·(1−p)) instead
    (heterogeneous links, mean still ``p_loss``)."""
    p = min(1.0, max(0.0, float(p_loss)))
    c = float(concentration)
    hetero = c > 0.0 and 0.0 < p < 1.0

    def init(key: jax.Array, n: int):
        k_r, k_run = jax.random.split(key)
        n_draw = n if _shard is None else _shard[1]
        if hetero:
            rates = jax.random.beta(k_r, c * p, c * (1.0 - p), (n_draw,))
        else:
            rates = jnp.full((n_draw,), p, jnp.float32)
        if _shard is not None:
            rates = _shard_slice(rates, _shard, n)
        return rates.astype(jnp.float32), k_run

    def step(state, attempting: jax.Array):
        rates, key = state
        k_u, k_next = jax.random.split(key)
        n_loc = attempting.shape[0]
        u = jax.random.uniform(k_u, (n_loc if _shard is None else _shard[1],))
        if _shard is not None:
            u = _shard_slice(u, _shard, n_loc)
        return attempting & (u >= rates), (rates, k_next)

    return ChannelProcess("erasure", True, init, step)


def aloha(num_channels: float = 2, _shard=None) -> ChannelProcess:
    """M-channel slotted ALOHA: attempting clients pick a channel uniformly;
    exactly-one occupancy delivers, collisions destroy every colliding
    upload.  The sharded form psums the per-channel contention counts over
    the fleet axis (collisions span shards)."""
    M = max(1, int(num_channels))

    def init(key: jax.Array, n: int):
        return key

    def step(key, attempting: jax.Array):
        k_c, k_next = jax.random.split(key)
        n_loc = attempting.shape[0]
        choice = jax.random.randint(
            k_c, (n_loc if _shard is None else _shard[1],), 0, M
        )
        if _shard is not None:
            choice = _shard_slice(choice, _shard, n_loc)
        counts = jnp.zeros((M,), jnp.int32).at[choice].add(
            attempting.astype(jnp.int32)
        )
        if _shard is not None:
            counts = jax.lax.psum(counts, _shard[0])
        return attempting & (counts[choice] == 1), k_next

    return ChannelProcess("aloha", True, init, step)


def fading(p_bad: float = 0.3, sojourn: float = 4.0, _shard=None) -> ChannelProcess:
    """Gilbert–Elliott per-client link: good delivers, bad is outage.
    Stationary bad fraction ``p_bad``; ``sojourn`` = 1/(g2b + b2g) sets the
    burstiness (mean bad sojourn sojourn/(1−p_bad) epochs, mean good sojourn
    sojourn/p_bad)."""
    pb = min(1.0, max(0.0, float(p_bad)))
    sojourn = max(1.0, float(sojourn))
    g2b = pb / sojourn  # good -> bad
    b2g = (1.0 - pb) / sojourn  # bad -> good

    def init(key: jax.Array, n: int):
        k_z, k_run = jax.random.split(key)
        n_draw = n if _shard is None else _shard[1]
        good = jax.random.bernoulli(k_z, 1.0 - pb, (n_draw,))
        if _shard is not None:
            good = _shard_slice(good, _shard, n)
        return good, k_run

    def step(state, attempting: jax.Array):
        good, key = state
        k_flip, k_next = jax.random.split(key)
        delivered = attempting & good
        n_loc = good.shape[0]
        # bernoulli(k, p) == uniform(k, p.shape, dtype(p)) < p: explicit
        # uniforms make the sliced sharded draw bit-exact by construction
        u = jax.random.uniform(k_flip, (n_loc if _shard is None else _shard[1],))
        if _shard is not None:
            u = _shard_slice(u, _shard, n_loc)
        flip = u < jnp.where(good, g2b, b2g)
        return delivered, (good ^ flip, k_next)

    return ChannelProcess("fading", True, init, step)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FACTORIES: dict = {
    "ideal": ideal,
    "erasure": erasure,
    "aloha": aloha,
    "fading": fading,
}


def make_channel(name: str, **params: float) -> ChannelProcess:
    """Build a named channel scenario (config-side:
    ``EHFLConfig(channel="name", channel_params=(("k", v),))``)."""
    if name not in _FACTORIES:
        raise ValueError(f"unknown channel scenario {name!r}; known: {SCENARIOS}")
    return _FACTORIES[name](**params)


def state_sharding_tree(name: str):
    """Pytree matching the scenario's state structure: True where the leaf
    is per-client (shard over the fleet axis), False where replicated
    (keys).  ``ideal`` is stateless (None)."""
    return {
        "ideal": None,
        "erasure": (True, False),  # (rates, key)
        "aloha": False,  # key
        "fading": (True, False),  # (good, key)
    }[name]


def make_sharded_channel(
    name: str, *, axis_name: str, n_global: int, **params: float
) -> ChannelProcess:
    """Client-sharded counterpart of :func:`make_channel` for the fleet path
    (DESIGN.md §9/§12): ``init(key, n_loc)`` / ``step(state, attempting_loc)``
    operate on this shard's window under ``shard_map``, with per-client state
    (erasure rates, fading link phases) local to the shard and keys
    replicated — every draw BIT-IDENTICAL to the single-device channel via
    global-draw-and-slice, and ALOHA's contention counts psum'd over the
    fleet axis (asserted in ``tests/test_channel.py``)."""
    if name not in _FACTORIES:
        raise ValueError(f"unknown channel scenario {name!r}; known: {SCENARIOS}")
    return _FACTORIES[name](_shard=(axis_name, n_global), **params)
