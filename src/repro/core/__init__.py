# The paper's primary contribution: feature-based semantics-aware (VAoI)
# scheduling for energy-harvesting federated learning.
from repro.core.simulator import Backend, EHFLConfig, run_simulation  # noqa: F401
from repro.core.vaoi import client_select, feature_distance, select_topk, vaoi_update  # noqa: F401
