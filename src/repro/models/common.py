"""Shared model building blocks: norms, activations, RoPE, initializers.

Everything is pure-functional: params are nested dicts of jnp arrays, layers
are functions ``(params, x, ...) -> y``.  This keeps the stack trivially
compatible with jax.jit / pjit / shard_map and with stacked-parameter
``lax.scan`` over layers.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int, dtype=jnp.float32) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(kind: str, p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated silu / plain gelu)
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, d: int, ff: int, act: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d, ff, dtype), "w_down": dense_init(k2, ff, d, dtype)}
    if act == "silu":  # gated
        p["w_gate"] = dense_init(k3, d, ff, dtype)
    return p


def apply_mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(act)
    return h @ p["w_down"]


def softmax_cross_entropy_per_token(
    logits: jax.Array, labels: jax.Array, impl: str = "gather"
) -> jax.Array:
    """Per-token CE (..., ) — no mean reduction."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    if impl == "onehot":
        hit = labels[..., None] == jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    else:
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, impl: str = "gather"
) -> jax.Array:
    """Mean token-level CE. logits (..., V) float; labels (...,) int.

    impl="gather": take_along_axis — simplest, but under GSPMD a gather over
    a vocab-sharded logits tensor forces an all-gather of the whole thing.
    impl="onehot": mask-and-reduce — the gold logit is a local reduction per
    vocab shard followed by a tiny cross-shard add; no logits all-gather
    (EXPERIMENTS.md §Perf iteration 1).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    if impl == "onehot":
        hit = labels[..., None] == jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    else:
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
