"""Minimal deterministic stand-in for ``hypothesis``, installed by conftest
ONLY when the real package is unavailable (this repo's property suites must
not silently vanish on a box without it).

It covers exactly the API surface the test files use — ``given``,
``settings``, ``strategies.integers/floats/tuples/sampled_from`` and
``extra.numpy.arrays`` — replaying a small, seeded, corner-biased example
sequence per test: draw 0 pins every argument at its minimum, draw 1 at its
maximum, the rest are pseudo-random from a per-test deterministic seed.  No
shrinking, no database, no deadlines; with real hypothesis installed this
module is never imported.
"""
from __future__ import annotations

import sys
import types
import zlib

import numpy as np

# keep runtimes bounded: property bodies here trace/compile jax programs per
# distinct shape, so cap the replayed examples regardless of @settings
_MAX_EXAMPLES_CAP = 8


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng, i):
        return self._draw(rng, i)


def integers(min_value, max_value):
    def draw(rng, i):
        if i == 0:
            return int(min_value)
        if i == 1:
            return int(max_value)
        return int(rng.integers(min_value, max_value + 1))

    return _Strategy(draw)


def floats(min_value=0.0, max_value=1.0, width=64, **_kw):
    def draw(rng, i):
        if i == 0:
            v = float(min_value)
        elif i == 1:
            v = float(max_value)
        else:
            v = float(rng.uniform(min_value, max_value))
        return float(np.float32(v)) if width == 32 else v

    return _Strategy(draw)


def booleans():
    return _Strategy(lambda rng, i: bool(i % 2) if i < 2 else bool(rng.integers(0, 2)))


def sampled_from(elements):
    elements = list(elements)

    def draw(rng, i):
        if i < len(elements):
            return elements[i]
        return elements[int(rng.integers(0, len(elements)))]

    return _Strategy(draw)


def tuples(*strategies):
    return _Strategy(lambda rng, i: tuple(s.draw(rng, i) for s in strategies))


def just(value):
    return _Strategy(lambda rng, i: value)


def arrays(dtype, shape, *, elements):
    def draw(rng, i):
        shp = shape.draw(rng, i) if isinstance(shape, _Strategy) else shape
        if isinstance(shp, int):
            shp = (shp,)
        n = int(np.prod(shp))
        if i < 2:  # corner draws pin EVERY element (all-min, then all-max)
            flat = [elements.draw(rng, i) for _ in range(n)]
        else:
            flat = [elements.draw(rng, 2 + k) for k in range(n)]
        return np.asarray(flat, dtype=dtype).reshape(shp)

    return _Strategy(draw)


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        n = min(getattr(fn, "_fallback_max_examples", None) or _MAX_EXAMPLES_CAP,
                _MAX_EXAMPLES_CAP)

        def wrapper():
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                kwargs = {name: s.draw(rng, i) for name, s in strategies.items()}
                fn(**kwargs)

        # plain attribute copies (not functools.wraps): pytest must see a
        # zero-argument signature, not fn's strategy parameters
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def install() -> None:
    """Register stub ``hypothesis`` modules in sys.modules."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__version__ = "0.0-fallback"

    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "tuples", "just"):
        setattr(strat, name, globals()[name])

    extra = types.ModuleType("hypothesis.extra")
    extra_np = types.ModuleType("hypothesis.extra.numpy")
    extra_np.arrays = arrays

    hyp.strategies = strat
    extra.numpy = extra_np
    hyp.extra = extra
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = extra_np
