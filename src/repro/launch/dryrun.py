import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, record memory/cost/collective analysis for the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be invoked as its own process (the XLA_FLAGS line above executes before
any jax import — 512 placeholder host devices).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--mode fsdp|tp]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import INPUT_SHAPES, get_config, input_specs, list_configs
from repro.configs.base import InputShape, ModelConfig
from repro.launch import hlo_analysis, sharding, steps
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models import decoder

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str | None:
    if shape.name == "long_500k" and cfg.is_encoder_decoder:
        # enc-dec: no sub-quadratic analogue for a 524k decoder context
        # (see DESIGN.md §5) — the only skipped pair family.
        return "enc-dec: 524k decoder context has no sliding-window analogue"
    return None


def decode_cache_plan(cfg: ModelConfig, shape: InputShape) -> tuple[int, bool]:
    """(cache length, rolling?) for decode shapes."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            # SSM layers are O(1); jamba's sparse attn layers keep full KV at B=1
            return shape.seq_len, False
        return cfg.long_context_window, True  # dense/MoE: rolling window
    if cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window), True
    return shape.seq_len, False


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    mode: str = "fsdp",
    remat: bool = True,
    seq_override: int | None = None,
    unroll: bool = False,
    ce_impl: str = "gather",
    embed_mode: str | None = None,
    act_sharding: bool = False,
    ce_chunk: int = 0,
    cross_cache: bool = False,
    ssm_chunk: int = 0,
    cache_batch_only: bool = False,
) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    import dataclasses

    if seq_override:
        shape = dataclasses.replace(shape, seq_len=seq_override)
    if ssm_chunk:
        cfg = dataclasses.replace(cfg, ssm_chunk=ssm_chunk)
    reason = skip_reason(cfg, shape)
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode,
        "unroll": unroll,
        "ce_impl": ce_impl,
        "embed_mode": embed_mode or "fsdp",
        "act_sharding": act_sharding,
    }
    if reason:
        rec["skipped"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    if act_sharding:
        from jax.sharding import NamedSharding, PartitionSpec as P

        dp = sharding.data_axes(mesh)
        decoder.set_activation_shardings(
            act=NamedSharding(mesh, P(dp, None, None)),
            logits=NamedSharding(mesh, P(dp, None, "model")),
        )
    else:
        decoder.set_activation_shardings()
    key = jax.random.PRNGKey(0)
    max_seq = shape.seq_len + cfg.num_prefix_tokens
    params_shape = jax.eval_shape(lambda: decoder.init_params(cfg, key, max_seq=max_seq))
    p_shard = sharding.params_shardings(params_shape, mesh, mode, embed_mode)
    p_abs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), params_shape, p_shard
    )
    specs = input_specs(cfg, shape)
    in_shard = sharding.input_shardings(specs, mesh)
    batch_abs = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=in_shard[k]) for k, v in specs.items()
    }

    t0 = time.time()
    if shape.kind == "train":
        step = steps.make_train_step(
            cfg, remat=remat, unroll=unroll, ce_impl=ce_impl, ce_chunk=ce_chunk
        )
        lowered = jax.jit(step, out_shardings=(sharding.replicated(mesh), p_shard)).lower(
            p_abs, batch_abs
        )
    elif shape.kind == "prefill":
        step = steps.make_prefill_step(cfg, unroll=unroll)
        lowered = jax.jit(step).lower(p_abs, batch_abs)
    else:  # decode
        cache_len, rolling = decode_cache_plan(cfg, shape)
        cache_shape = jax.eval_shape(
            lambda: decoder.init_cache(
                cfg, shape.global_batch, cache_len, rolling, cross_cache=cross_cache
            )
        )
        c_shard = sharding.cache_shardings(cache_shape, mesh, cfg, batch_only=cache_batch_only)
        c_abs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), cache_shape, c_shard
        )
        tok_abs = batch_abs["tokens"]
        pos_abs = batch_abs["positions"]
        if cfg.is_encoder_decoder and cross_cache:
            # beyond-paper: cross K/V cached at prefill; decode needs no encoder input
            step = steps.make_serve_step(cfg, rolling, unroll=unroll)
            lowered = jax.jit(step).lower(p_abs, c_abs, tok_abs, pos_abs)
        elif cfg.is_encoder_decoder:
            step = steps.make_serve_step(cfg, rolling, with_encoder=True, unroll=unroll)
            enc_abs = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                cfg.dtype,
                sharding=sharding.input_shardings(
                    {"e": jax.ShapeDtypeStruct((shape.global_batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)},
                    mesh,
                )["e"],
            )
            lowered = jax.jit(step).lower(p_abs, c_abs, tok_abs, pos_abs, enc_abs)
        else:
            step = steps.make_serve_step(cfg, rolling, unroll=unroll)
            lowered = jax.jit(step).lower(p_abs, c_abs, tok_abs, pos_abs)
    rec["lower_s"] = round(time.time() - t0, 1)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    # --- memory ---
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)}

    # --- cost ---
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax returns one dict per program
            ca = ca[0] if ca else {}
        flops = float(ca.get("flops", 0.0))
        hbm = float(ca.get("bytes accessed", 0.0))
        rec["cost"] = {"flops": flops, "bytes_accessed": hbm}
    except Exception as e:
        rec["cost"] = {"error": str(e)}
        flops, hbm = 0.0, 0.0

    # --- collectives + roofline ---
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    rec["collectives"] = {k: v for k, v in coll.items() if k != "counts"}
    rec["collective_counts"] = coll["counts"]
    terms = hlo_analysis.roofline_terms(
        flops, hbm, coll["total"], PEAK_FLOPS_BF16, HBM_BW, ICI_BW
    )
    rec["roofline"] = terms
    # model flops: 6*N_active*D for train, 2*N_active*D for inference fwd
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    model_flops_total = factor * n_active * tokens
    rec["model_flops_per_chip"] = model_flops_total / n_chips
    rec["useful_flop_ratio"] = (model_flops_total / n_chips) / flops if flops else None
    rec["n_chips"] = n_chips
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer loop for analysis-grade cost/collective counting")
    ap.add_argument("--ce", default="gather", choices=["gather", "onehot"])
    ap.add_argument("--embed-mode", default=None, choices=[None, "fsdp", "vocab_only"])
    ap.add_argument("--act-sharding", action="store_true",
                    help="pin activations to batch-sharded layout (§Perf it.3)")
    ap.add_argument("--ce-chunk", type=int, default=0,
                    help="chunked LM-head+CE over the sequence (§Perf it.6)")
    ap.add_argument("--cross-cache", action="store_true",
                    help="enc-dec decode with cached cross K/V (§Perf it.7)")
    ap.add_argument("--ssm-chunk", type=int, default=0, help="override SSD chunk length (§Perf it.9)")
    ap.add_argument("--cache-batch-only", action="store_true",
                    help="KV cache sharded on batch only (§Perf it.8)")
    ap.add_argument("--seq", type=int, default=None, help="override seq_len (debug)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    pairs = []
    if args.all:
        for a in list_configs():
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    results = []
    for arch, shape in pairs:
        tag = f"{arch}|{shape}|{'2x16x16' if args.multi_pod else '16x16'}|{args.mode}"
        try:
            rec = run_one(arch, shape, args.multi_pod, args.mode, not args.no_remat,
                          args.seq, args.unroll, args.ce, args.embed_mode, args.act_sharding,
                          args.ce_chunk, args.cross_cache, args.ssm_chunk, args.cache_batch_only)
            status = "SKIP" if "skipped" in rec else "OK"
            print(f"[{status}] {tag} "
                  + (rec.get("skipped", "")
                     or f"compile={rec['compile_s']}s flops={rec['cost'].get('flops', 0):.3g} "
                       f"coll={rec['collectives']['total']:.3g}B bottleneck={rec['roofline']['bottleneck']}"))
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mode": args.mode,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {tag} {type(e).__name__}: {e}")
        results.append(rec)
        suffix = ""
        if args.unroll:
            suffix += "__unroll"
        if args.ce != "gather":
            suffix += f"__ce-{args.ce}"
        if args.embed_mode and args.embed_mode != "fsdp":
            suffix += f"__emb-{args.embed_mode}"
        if args.act_sharding:
            suffix += "__act"
        if args.ce_chunk:
            suffix += f"__cechunk{args.ce_chunk}"
        if args.cross_cache:
            suffix += "__xcache"
        out = args.out or RESULTS_DIR / (
            f"{arch}__{shape}__{'mp' if args.multi_pod else 'sp'}__{args.mode}{suffix}.json"
        )
        Path(out).write_text(json.dumps(rec, indent=2, default=str))

    n_ok = sum(1 for r in results if "error" not in r and "skipped" not in r)
    n_skip = sum(1 for r in results if "skipped" in r)
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n== dry-run summary: {n_ok} ok / {n_skip} skipped / {n_fail} failed ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
