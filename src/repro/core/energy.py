"""Energy-harvesting battery substrate (§III-C, Eq. 3/4) — the slot-level
dynamics of one FL epoch, fully vectorized over clients and scanned over
slots.

Semantics (faithful to the paper):
  * at the beginning of each slot a unit of energy arrives w.p. p_bc
    (Bernoulli), battery capped at E_max;
  * actions: idle (0 energy), transmit (1 slot, 1 unit),
    train (kappa slots, kappa units);  strict energy causality;
  * a training run occupies kappa consecutive slots; we require
    start_slot <= S - kappa so runs complete within the epoch (FedBacys'
    deadline semantics; adopted for all policies — see DESIGN.md §6);
  * a completed update is transmitted at the first later slot with E >= 1.

``scan_epoch`` is policy-parametric through ``want_fn(slot, state) -> (N,)``,
the mask of clients that would *like* to start training at this slot.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import harvest as harvest_lib


class SlotState(NamedTuple):
    battery: jax.Array  # (N,) int32
    started: jax.Array  # (N,) bool — started training this epoch
    start_slot: jax.Array  # (N,) int32 (S if not started)
    pending: jax.Array  # (N,) bool — has an unsent message
    uploaded: jax.Array  # (N,) bool — uploaded during this epoch
    counter: jax.Array  # (N,) int32 — FedBacys-Odd opportunity counter
    energy_used: jax.Array  # (N,) int32 — cumulative units consumed
    key: jax.Array
    # HarvestProcess state (DESIGN.md §7); None -> initialized from ``key``
    # inside ``scan_epoch`` (the memoryless/per-epoch-reseed path).
    harvest: Any = None
    # DataStream state (DESIGN.md §10).  Per-epoch streams step in
    # ``simulator.epoch_body`` before the slot scan; the field rides the
    # scan untouched so slot-granular arrival processes can couple to the
    # energy dynamics the way harvest state does.
    stream: Any = None


def harvest_step(key: jax.Array, battery: jax.Array, p_bc: float, e_max: int) -> Tuple[jax.Array, jax.Array]:
    """Legacy single-step Bernoulli harvest (Eq. 3).  Kept as the reference
    the ``bernoulli`` HarvestProcess is tested bit-identical against."""
    k1, k2 = jax.random.split(key)
    charge = jax.random.bernoulli(k1, p_bc, battery.shape).astype(battery.dtype)
    return jnp.minimum(battery + charge, e_max), k2


def scan_epoch(
    state: SlotState,
    *,
    S: int,
    kappa: int,
    e_max: int,
    want_fn: Callable[[jax.Array, SlotState], jax.Array],
    p_bc: float | None = None,
    process: harvest_lib.HarvestProcess | None = None,
    count_opportunity_fn: Callable[[jax.Array, SlotState], jax.Array] | None = None,
    tx_allowed: jax.Array | None = None,
) -> SlotState:
    """Run S slots of battery/action dynamics. Returns the post-epoch state.

    Energy arrivals come from ``process`` (any :class:`HarvestProcess`);
    passing ``p_bc`` alone is the backward-compatible Bernoulli shorthand.
    If ``state.harvest`` is None the process state is initialized from
    ``state.key`` (for ``bernoulli`` this reproduces the seed behavior
    bit-for-bit); persistent processes should thread their state in/out via
    the ``harvest`` field instead.

    ``count_opportunity_fn`` (FedBacys-Odd): mask of clients whose opportunity
    counter increments this slot (criteria (i)-(iii) met).

    ``tx_allowed`` (lossy-channel backoff, DESIGN.md §12): (N,) bool mask of
    clients permitted to transmit this epoch — a client under retry backoff
    holds its pending message (and its energy) without contending.  ``None``
    (and an all-True mask) leaves the dynamics unchanged.
    """
    if process is None:
        if p_bc is None:
            raise ValueError("scan_epoch needs either p_bc or a HarvestProcess")
        process = harvest_lib.bernoulli(p_bc)
    if state.harvest is None:
        state = state._replace(harvest=process.init(state.key, state.battery.shape[0]))

    def slot_body(st: SlotState, s: jax.Array) -> Tuple[SlotState, None]:
        charge, hstate = process.step(st.harvest, st.battery)
        battery = jnp.minimum(st.battery + charge.astype(st.battery.dtype), e_max)
        # advance the per-slot key exactly as the seed code did (it was the
        # harvest chain then), so want_fn/count_opportunity_fn implementations
        # drawing randomness from st.key keep a fresh key every slot
        key = jax.random.split(st.key)[1]
        st = st._replace(battery=battery, harvest=hstate, key=key)
        busy = st.started & (s >= st.start_slot) & (s < st.start_slot + kappa)
        # --- opportunity counting (before the odd-gate decides) ---
        counter = st.counter
        if count_opportunity_fn is not None:
            opp = count_opportunity_fn(s, st) & ~busy
            counter = counter + opp.astype(counter.dtype)
            st = st._replace(counter=counter)
        # --- start training ---
        want = want_fn(s, st)
        can = (
            (~st.started)
            & (~busy)
            & (~st.pending)
            & (st.battery >= kappa)
            & (s <= S - kappa)
        )
        start = want & can
        battery = st.battery - jnp.where(start, kappa, 0)
        energy_used = st.energy_used + jnp.where(start, kappa, 0)
        started = st.started | start
        start_slot = jnp.where(start, s, st.start_slot)
        busy = started & (s >= start_slot) & (s < start_slot + kappa)
        # --- completion -> message pending ---
        done_now = started & (s + 1 == start_slot + kappa)
        pending = st.pending | done_now
        # --- transmit (cannot transmit while busy; 1 unit) ---
        can_tx = pending & ~busy & ~done_now & (battery >= 1) & ~st.uploaded
        if tx_allowed is not None:
            can_tx = can_tx & tx_allowed
        battery = battery - can_tx.astype(battery.dtype)
        energy_used = energy_used + can_tx.astype(energy_used.dtype)
        pending = pending & ~can_tx
        uploaded = st.uploaded | can_tx
        return (
            st._replace(
                battery=battery,
                started=started,
                start_slot=start_slot,
                pending=pending,
                uploaded=uploaded,
                energy_used=energy_used,
            ),
            None,
        )

    state, _ = jax.lax.scan(slot_body, state, jnp.arange(S))
    return state


def init_slot_state(n: int, key: jax.Array, battery: jax.Array | None = None, S: int = 30) -> SlotState:
    z = jnp.zeros((n,), jnp.int32)
    f = jnp.zeros((n,), bool)
    return SlotState(
        battery=z if battery is None else battery,
        started=f,
        start_slot=jnp.full((n,), S, jnp.int32),
        pending=f,
        uploaded=f,
        counter=z,
        energy_used=z,
        key=key,
    )
