"""Step functions lowered by the dry-run and used by the at-scale drivers.

train_step : one FL client local SGD step on the LM objective (the paper's
             BATCHTRAIN at modern scale) — lowered for training shapes.
prefill    : full-sequence forward, last-position logits (serving prefill).
serve_step : single-token decode against the KV/SSM cache (decode shapes).
"""
from __future__ import annotations

from typing import Dict

import jax

from repro.configs.base import ModelConfig
from repro.models import decoder
from repro.optim import sgd_update


def make_train_step(
    cfg: ModelConfig,
    lr: float = 0.01,
    remat: bool = True,
    unroll: bool = False,
    ce_impl: str = "gather",
    ce_chunk: int = 0,
):
    def train_step(params, batch: Dict[str, jax.Array]):
        def loss(p):
            l, _ = decoder.loss_fn(
                cfg, p, batch, remat=remat, unroll=unroll, ce_impl=ce_impl, ce_chunk=ce_chunk
            )
            return l

        l, grads = jax.value_and_grad(loss)(params)
        return l, sgd_update(params, grads, lr)

    return train_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False):
    def prefill(params, batch: Dict[str, jax.Array]):
        logits, _ = decoder.forward_logits(
            cfg,
            params,
            batch["tokens"],
            prefix_embeddings=batch.get("prefix_embeddings"),
            encoder_frames=batch.get("encoder_frames"),
            last_only=True,
            unroll=unroll,
        )
        return logits

    return prefill


def make_serve_step(
    cfg: ModelConfig, rolling: bool = False, with_encoder: bool = False, unroll: bool = False
):
    if with_encoder:
        def serve_step(params, cache, tokens, positions, encoder_out):
            return decoder.decode_step(
                cfg, params, cache, tokens, positions, rolling=rolling,
                encoder_out=encoder_out, unroll=unroll,
            )
    else:
        def serve_step(params, cache, tokens, positions):
            return decoder.decode_step(
                cfg, params, cache, tokens, positions, rolling=rolling, unroll=unroll
            )

    return serve_step
