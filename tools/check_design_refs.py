#!/usr/bin/env python
"""Fail if any `DESIGN.md §N` reference in the source tree points at a
section that does not exist in DESIGN.md (CI docs job; also runnable
locally: `python tools/check_design_refs.py`)."""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
SCAN_DOCS = ("README.md",)  # root docs cite sections too (e.g. §8/§9)
REF = re.compile(r"DESIGN\.md\s+§(\d+)")
HEADING = re.compile(r"^#+\s+§(\d+)\b", re.M)


def main() -> int:
    design = REPO / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist")
        return 1
    sections = {int(n) for n in HEADING.findall(design.read_text())}
    missing = []
    paths = [p for d in SCAN_DIRS for p in sorted((REPO / d).rglob("*.py"))]
    paths += [REPO / doc for doc in SCAN_DOCS if (REPO / doc).exists()]
    for path in paths:
        for i, line in enumerate(path.read_text().splitlines(), 1):
            for n in REF.findall(line):
                if int(n) not in sections:
                    missing.append(f"{path.relative_to(REPO)}:{i} -> §{n}")
    if missing:
        print("FAIL: dangling DESIGN.md section references:")
        print("\n".join(f"  {m}" for m in missing))
        print(f"DESIGN.md defines sections: {sorted(sections)}")
        return 1
    print(f"OK: all DESIGN.md §N references resolve (sections {sorted(sections)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
