"""Benchmark harness — one module per paper table/figure + infra rooflines.

Prints ``name,us_per_call,derived`` CSV.  Default is the quick protocol
(CPU-feasible, same structural constants as the paper); ``--full`` runs the
3x3 (alpha x p_bc) grid at larger N/T.

The ``fleet``, ``stream``, and ``channel`` suites additionally write
machine-readable ``BENCH_*.json`` perf-trajectory files at the repo root
(validated by ``tools/check_bench.py``).

Every suite runs under a wall-clock watchdog (``--suite-timeout``, default
900 s): a suite that hangs — a deadlocked collective, a runaway compile —
kills the harness with exit 1 instead of wedging CI until the job-level
timeout reaps it with no attribution.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time


def _watchdog(suite: str, timeout_s: float) -> threading.Timer:
    """Arm a wall-clock kill switch for one suite.  ``os._exit`` (not
    ``sys.exit``) so a C-level hang inside XLA can't swallow the exit —
    a watchdog that raises in a side thread would be silently dropped."""

    def _kill() -> None:
        print(
            f"{suite}/TIMEOUT,0,exceeded {timeout_s:.0f}s wall clock",
            file=sys.stderr, flush=True,
        )
        os._exit(1)

    t = threading.Timer(timeout_s, _kill)
    t.daemon = True
    t.start()
    return t


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only",
        default=None,
        help="comma list from: fig4,fig5,fig6,roofline,kernels,ablation,fleet,stream,channel",
    )
    ap.add_argument(
        "--suite-timeout", type=float, default=900.0,
        help="per-suite wall-clock limit in seconds; a suite that exceeds it "
        "fails the harness (exit 1) instead of hanging",
    )
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        ablation_mu, channel_bench, fig4_f1, fig5_vaoi, fig6_energy,
        fleet_bench, kernels_bench, roofline, stream_bench,
    )

    suites = {
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
        "fig4": fig4_f1.run,
        "fig5": fig5_vaoi.run,
        "fig6": fig6_energy.run,
        "ablation": ablation_mu.run,
        "fleet": fleet_bench.run,
        "stream": stream_bench.run,
        "channel": channel_bench.run,
    }
    wanted = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    failed = []
    for name in wanted:
        if name not in suites:
            print(f"{name}/ERROR,0,UnknownSuite", file=sys.stderr)
            failed.append(name)
            continue
        t0 = time.time()
        watchdog = _watchdog(name, args.suite_timeout)
        try:
            rows = suites[name](quick=quick)
        except Exception as e:  # keep the harness going, but record the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            failed.append(name)
            continue
        finally:
            watchdog.cancel()
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        print(f"{name}/_suite_wall,{(time.time()-t0)*1e6:.0f},ok", file=sys.stderr)
    if failed:
        # CI gates on this: a broken suite must fail the job, not exit 0
        print(f"FAILED suites: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
