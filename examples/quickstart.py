"""Quickstart: VAoI-scheduled EHFL vs greedy FedAvg in ~a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_simulation
from repro.data import make_federated_dataset
from repro.fl import cnn_backend

cnn = CNNConfig(name="quick", image_size=16, conv_channels=(8, 8, 16, 16, 32, 32), fc_dims=(64, 32))
data = make_federated_dataset(
    jax.random.PRNGKey(0), num_clients=12, samples_per_client=60,
    alpha=0.1, test_size=200, image_size=16,
)
backend = cnn_backend(cnn)

print(f"{'policy':<14} {'final F1':>9} {'energy':>8} {'trainings':>10}")
for policy in ("vaoi", "fedavg", "fedbacys", "fedbacys_odd"):
    cfg = EHFLConfig(
        num_clients=12, epochs=25, slots_per_epoch=30, kappa=20, p_bc=0.3,
        k=4, mu=0.5, e_max=25, policy=policy, eval_every=25, probe_size=15, lr=0.05,
    )
    out = run_simulation(cfg, backend, data)
    m = out["metrics"]
    print(
        f"{policy:<14} {float(m['f1'][-1]):>9.4f} {float(m['total_energy']):>8.0f} "
        f"{int(m['n_started'].sum()):>10d}"
    )
