"""VAoI-scheduled federated finetuning of an assigned-architecture LM —
the paper's scheduler driving a modern transformer client (reduced config
on CPU; the same path targets the production mesh via repro.launch).

  PYTHONPATH=src python examples/lm_federated.py --arch qwen1.5-0.5b --rounds 3
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=6)
    args = ap.parse_args()
    # thin wrapper over the launcher (same public entry point used at scale)
    sys.exit(
        subprocess.call(
            [
                sys.executable, "-m", "repro.launch.train",
                "--arch", args.arch, "--reduced",
                "--clients", str(args.clients),
                "--rounds", str(args.rounds),
                "--k", "2", "--steps-per-round", "4",
            ]
        )
    )
