"""Model correctness: decode==forward consistency, SSD exactness, MoE
routing semantics, attention windowing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import attention, decoder, moe, ssd


@pytest.mark.parametrize(
    "arch", ["qwen1.5-0.5b", "starcoder2-3b", "mamba2-1.3b", "jamba-v0.1-52b", "whisper-large-v3"]
)
def test_decode_matches_forward(arch, rng):
    cfg = reduced(get_config(arch))
    if cfg.num_experts:  # avoid capacity-drop mismatch: no drops at high cf
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = decoder.init_params(cfg, rng, max_seq=64)
    B, S = 2, 12
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    enc_out = None
    kw = {}
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model))
        kw["encoder_frames"] = frames
    logits_full, _ = decoder.forward_logits(cfg, params, tokens, **kw)
    if cfg.is_encoder_decoder:
        enc_out = decoder._encode(cfg, params, frames)
    cache = decoder.init_cache(cfg, B, 32)
    outs = []
    for t in range(S):
        lt, cache = decoder.decode_step(
            cfg, params, cache, tokens[:, t : t + 1], jnp.full((B,), t), encoder_out=enc_out
        )
        outs.append(lt)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32), np.asarray(logits_dec, np.float32), atol=2e-4, rtol=1e-3
    )


def test_ssd_chunked_equals_recurrence(rng):
    cfg = reduced(get_config("mamba2-1.3b"))
    p = ssd.init_ssd(rng, cfg, jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(rng, (B, S, cfg.d_model)) * 0.5
    y_full = ssd.ssd_forward(cfg, p, x)
    cache = ssd.init_ssd_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        yt, cache = ssd.ssd_decode(cfg, p, x[:, t : t + 1], cache)
        ys.append(yt)
    np.testing.assert_allclose(y_full, jnp.concatenate(ys, 1), atol=1e-4)


@pytest.mark.parametrize("chunk", [4, 8, 16, 24])
def test_ssd_chunk_invariance(chunk, rng):
    cfg = dataclasses.replace(reduced(get_config("mamba2-1.3b")), ssm_chunk=chunk)
    cfg64 = dataclasses.replace(cfg, ssm_chunk=64)
    p = ssd.init_ssd(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 24, cfg.d_model)) * 0.5
    np.testing.assert_allclose(
        ssd.ssd_forward(cfg, p, x), ssd.ssd_forward(cfg64, p, x), atol=1e-4
    )


def test_ssd_init_state_carry(rng):
    """Chunked SSD with an initial state == processing the concatenation."""
    cfg = reduced(get_config("mamba2-1.3b"))
    nh, hp, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    B, S1, S2 = 1, 16, 16
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (B, S1 + S2, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S1 + S2, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S1 + S2, ds))
    Cm = jax.random.normal(ks[4], (B, S1 + S2, ds))
    y_all, final_all = ssd.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y1, s1 = ssd.ssd_chunked(x[:, :S1], dt[:, :S1], A, Bm[:, :S1], Cm[:, :S1], chunk=8)
    y2, s2 = ssd.ssd_chunked(
        x[:, S1:], dt[:, S1:], A, Bm[:, S1:], Cm[:, S1:], chunk=8, init_state=s1
    )
    np.testing.assert_allclose(y_all[:, S1:], y2, atol=1e-4)
    np.testing.assert_allclose(final_all, s2, atol=1e-4)


def test_moe_group_invariance(rng):
    """Routing in groups must equal one-group routing when capacity is ample."""
    cfg = dataclasses.replace(reduced(get_config("deepseek-moe-16b")), capacity_factor=8.0)
    p = moe.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 16, cfg.d_model))
    y1, _ = moe.apply_moe(cfg, p, x, group_size=4)
    y2, _ = moe.apply_moe(cfg, p, x, group_size=16)
    np.testing.assert_allclose(y1, y2, atol=1e-5)


def test_moe_capacity_drops_tokens(rng):
    """With capacity_factor ~ 0 most tokens are dropped -> output ~ shared only."""
    cfg = dataclasses.replace(
        reduced(get_config("deepseek-moe-16b")), capacity_factor=1e-6, num_shared_experts=0
    )
    p = moe.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (1, 8, cfg.d_model))
    y, _ = moe.apply_moe(cfg, p, x, group_size=8)
    # capacity 1 per expert per group: at most E tokens routed; most output
    # rows for dropped tokens must be exactly zero
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert int((norms == 0).sum()) >= 8 - cfg.num_experts


def test_moe_router_gradients_flow(rng):
    cfg = reduced(get_config("llama4-scout-17b-a16e"))
    p = moe.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (1, 8, cfg.d_model))

    def loss(p):
        y, aux = moe.apply_moe(cfg, p, x)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0


def test_sliding_window_masks_far_context(rng):
    """With window w, tokens > w in the past cannot influence the output."""
    cfg = dataclasses.replace(reduced(get_config("starcoder2-3b")), sliding_window=4)
    p = attention.init_attn(rng, cfg, jnp.float32)
    S = 12
    x1 = jax.random.normal(rng, (1, S, cfg.d_model))
    x2 = x1.at[:, 0].add(100.0)  # perturb a token far outside the window
    pos = jnp.arange(S)
    o1 = attention.attn_forward(cfg, p, x1, pos, window=4)
    o2 = attention.attn_forward(cfg, p, x2, pos, window=4)
    np.testing.assert_allclose(o1[:, 8:], o2[:, 8:], atol=1e-4)
    assert float(jnp.abs(o1[:, 0] - o2[:, 0]).max()) > 1e-3  # but it does affect itself


def test_rolling_cache_decode_matches_window_forward(rng):
    """Rolling-buffer decode == full forward with sliding-window mask."""
    cfg = dataclasses.replace(
        reduced(get_config("qwen1.5-0.5b")), sliding_window=0, use_rope=True
    )
    params = decoder.init_params(cfg, rng, max_seq=64)
    B, S, W = 1, 20, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits_win, _ = decoder.forward_logits(cfg, params, tokens, window=W)
    cache = decoder.init_cache(cfg, B, W, rolling=True)
    outs = []
    for t in range(S):
        lt, cache = decoder.decode_step(
            cfg, params, cache, tokens[:, t : t + 1], jnp.full((B,), t), rolling=True
        )
        outs.append(lt)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_win, np.float32), np.asarray(logits_dec, np.float32), atol=2e-4, rtol=1e-3
    )


def test_vlm_prefix_changes_output(rng):
    cfg = reduced(get_config("internvl2-2b"))
    params = decoder.init_params(cfg, rng, max_seq=64)
    tokens = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    pe1 = jnp.zeros((1, cfg.num_prefix_tokens, cfg.d_model))
    pe2 = jax.random.normal(rng, (1, cfg.num_prefix_tokens, cfg.d_model))
    l1, _ = decoder.forward_logits(cfg, params, tokens, prefix_embeddings=pe1)
    l2, _ = decoder.forward_logits(cfg, params, tokens, prefix_embeddings=pe2)
    assert l1.shape == (1, 8, cfg.vocab_size)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


def test_whisper_cross_cache_decode_matches_forward(rng):
    """Cached cross K/V (no per-token encoder re-projection) is exact."""
    cfg = reduced(get_config("whisper-large-v3"))
    params = decoder.init_params(cfg, rng, max_seq=64)
    B, S = 2, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model))
    logits_full, _ = decoder.forward_logits(cfg, params, tokens, encoder_frames=frames)
    enc = decoder._encode(cfg, params, frames)
    cache = decoder.prefill_cross_cache(
        cfg, params, decoder.init_cache(cfg, B, 32, cross_cache=True), enc
    )
    outs = []
    for t in range(S):
        lt, cache = decoder.decode_step(cfg, params, cache, tokens[:, t : t + 1], jnp.full((B,), t))
        outs.append(lt)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32),
        np.asarray(jnp.concatenate(outs, 1), np.float32),
        atol=2e-4, rtol=1e-3,
    )


def test_ssd_forward_kernel_path_matches(rng):
    """ssd_forward(use_kernel=True) routes through the Pallas ssd_scan kernel
    and matches the pure-jnp chunked path."""
    cfg = reduced(get_config("mamba2-1.3b"))
    p = ssd.init_ssd(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 24, cfg.d_model)) * 0.5
    y_jnp = ssd.ssd_forward(cfg, p, x)
    y_ker = ssd.ssd_forward(cfg, p, x, use_kernel=True)
    np.testing.assert_allclose(y_jnp, y_ker, atol=1e-4, rtol=1e-4)
