# The paper's primary contribution: feature-based semantics-aware (VAoI)
# scheduling for energy-harvesting federated learning.
from repro.core.channel import SCENARIOS as CHANNEL_SCENARIOS  # noqa: F401
from repro.core.channel import ChannelProcess, make_channel  # noqa: F401
from repro.core.fleet import run_fleet  # noqa: F401
from repro.core.harvest import SCENARIOS, HarvestProcess, make_process  # noqa: F401
from repro.core.simulator import (  # noqa: F401
    Backend,
    EHFLConfig,
    init_carry,
    make_epoch_fn,
    run_batch,
    run_simulation,
)
from repro.core.vaoi import client_select, feature_distance, select_topk, vaoi_update  # noqa: F401
from repro.data.stream import SCENARIOS as STREAM_SCENARIOS  # noqa: F401
from repro.data.stream import DataStream, make_stream  # noqa: F401
