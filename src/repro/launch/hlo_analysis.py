"""Post-SPMD HLO analysis: collective-traffic accounting + roofline terms.

``collective_bytes`` parses the per-device HLO module text and sums the
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Per op we count max(sum of operand bytes, output
bytes) — a link-traffic proxy (bytes that must cross ICI at least once).
The resulting number is PER DEVICE, so the roofline collective term is
``bytes / ICI_BW`` directly (equivalent to total/(chips*link_bw)).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind byte totals (per device) from post-SPMD HLO."""
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match assignment lines: %name = TYPE[dims] op-name(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        if "-done(" in rhs:  # avoid double counting start/done pairs
            continue
        # output shape(s): everything before the op name
        head = rhs[: opm.start()]
        out_bytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
        # operand shapes: inside the parens (HLO prints operand values w/o shapes,
        # so rely on output bytes; for reduce-scatter the input is bigger ->
        # approximate traffic with output for AG/AR, output*world for RS is
        # overkill; output bytes is the standard per-device proxy)
        totals[op] += out_bytes
        counts[op] += 1
    totals["total"] = sum(totals[k] for k in _COLLECTIVES)
    totals["counts"] = counts  # type: ignore[assignment]
    return totals


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float,
) -> Dict[str, float]:
    """All inputs are PER-DEVICE quantities; returns seconds per term."""
    t_compute = flops / peak_flops
    t_memory = hbm_bytes / hbm_bw
    t_collective = coll_bytes / ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1).replace("_s", "")
    return terms
