"""Fleet-scale epoch throughput: the client-sharded simulator
(``core/fleet.py``, DESIGN.md §9) swept over N on virtual host devices.

Standalone it virtualizes 8 CPU devices (the SNIPPETS.md
``--xla_force_host_platform_device_count`` idiom — the flag must be set
before jax initializes, which is why it happens at import, guarded on jax
not being loaded yet) and times one epoch of the jitted sharded program per
fleet size.  Under ``benchmarks/run.py`` it uses whatever devices exist.

Results go to stdout CSV (the harness protocol) AND to ``BENCH_fleet.json``
at the repo root — the machine-readable perf-trajectory file.  Every run
overwrites it with rows for the CURRENT topology (the ``devices``/``shards``
fields record which); the committed baseline is the standalone 8-device run.

  PYTHONPATH=src python benchmarks/fleet_bench.py            # N=1k..4k, 8 devices
  PYTHONPATH=src python benchmarks/fleet_bench.py --full     # N up to 64k
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

if __name__ == "__main__" and "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8"
        ).strip()

import jax
import jax.numpy as jnp

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_fleet.json"

# micro CNN: 3 pools need 6 convs; image 8 -> 1x1 spatial, ~360 params, so
# msg_params stays ~100 MB even at N=64k
_MICRO = dict(image_size=8, conv_channels=(2, 2, 2, 2, 2, 2), fc_dims=(8,))


def _world(num_clients: int, samples: int = 8):
    from repro.configs.cifar_cnn import CNNConfig
    from repro.data import make_federated_dataset
    from repro.fl import cnn_backend

    cnn = CNNConfig(name="fleet-micro", **_MICRO)
    data = make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=num_clients,
        samples_per_client=samples, alpha=0.5, test_size=64, image_size=8,
    )
    return data, cnn_backend(cnn)


def bench_one(
    num_clients: int, policy: str = "vaoi", reps: int = 3, compact: bool = False
) -> dict:
    """Time one jitted epoch of the sharded fleet program at this N.

    ``compact`` flips the active-set compaction of DESIGN.md §11: with the
    paper's k=10 budget only the 10 scheduled clients run the kappa-step
    SGD scan, so the dominant training FLOPs shrink ~N/k while the slot
    dynamics/probe pass stay N-wide — the dense-vs-compact row pairs
    measure exactly that lever."""
    from repro.core import EHFLConfig
    from repro.core.fleet import fleet_program

    cfg = EHFLConfig(
        num_clients=num_clients, epochs=1, slots_per_epoch=8, kappa=4,
        p_bc=0.3, k=10, mu=0.5, e_max=8,
        policy=policy, eval_every=1, probe_size=4,
        compact="auto" if compact else False,
    )
    data, backend = _world(num_clients)
    carry, scan_chunk, sharded, mesh = fleet_program(cfg, backend, data)
    ts = jnp.arange(1)
    args = (ts, sharded["images"], sharded["labels"])

    t0 = time.time()
    carry2, _ = jax.block_until_ready(scan_chunk(carry, *args))
    compile_s = time.time() - t0
    t0 = time.time()
    for _ in range(reps):
        carry2, _ = jax.block_until_ready(scan_chunk(carry2, *args))
    epoch_s = (time.time() - t0) / reps
    return {
        "N": num_clients,
        "shards": mesh.shape["data"],
        "policy": policy,
        "compact": compact,
        "k": cfg.k,
        "epoch_s": round(epoch_s, 4),
        "compile_s": round(compile_s, 2),
        "clients_per_s": round(num_clients / epoch_s, 1),
    }


def run(quick: bool = True) -> list:
    """benchmarks/run.py suite entry: sweep N x {dense, compact}, write
    BENCH_fleet.json, return the harness CSV rows."""
    ns = (1024, 4096) if quick else (1024, 4096, 16384, 65536)
    rows = [bench_one(n, compact=c) for n in ns for c in (False, True)]
    OUT.write_text(json.dumps({
        "bench": "fleet",
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        # host fingerprint: tools/check_bench.py only gates throughput
        # against a baseline measured on a comparable machine
        "cpus": os.cpu_count(),
        "quick": quick,
        "rows": rows,
    }, indent=2))
    return [
        {
            "name": f"fleet/N{r['N']}_shards{r['shards']}"
            + ("_compact" if r["compact"] else ""),
            "us_per_call": r["epoch_s"] * 1e6,
            "derived": f"{r['clients_per_s']:.0f}clients/s",
        }
        for r in rows
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="sweep N up to 64k")
    args = ap.parse_args()
    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")
    print("name,us_per_call,derived")
    for r in run(quick=not args.full):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
