"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,  # no MLP: mamba2 blocks only
        vocab_size=50280,
        attn_period=0,  # attention-free
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        use_rope=False,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
        source="arXiv:2405.21060",
    )
)
