"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained MoE, 2 shared + 64 routed top-6."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        num_experts=64,
        num_shared_experts=2,
        experts_per_token=6,
        moe_period=1,
        rope_theta=10_000.0,
        dtype=jnp.bfloat16,
        source="arXiv:2401.06066",
    )
)
