"""Pallas TPU kernel: fused Mamba2 SSD scan (state-space duality).

One kernel fuses, per (batch, head) and sequentially over chunks:
  * the intra-chunk quadratic form  y_diag = (C Bᵀ ∘ L) · (x·dt)   (MXU)
  * the inter-chunk contribution    y_off  = exp(a⁺) · (C · Sᵀ)
  * the state recurrence            S' = S·exp(Σa) + (x·dt)ᵀ·(B·decay)

The running state S (hp × ds) lives in VMEM scratch and is carried across
the innermost (chunk) grid dimension — the same accumulator pattern as
flash attention.  This removes the (B, nh, nc, L, L) fp32 ``Lmat`` and the
(B, nc, nh, hp, ds) per-chunk state tensors from HBM entirely: §Roofline
identified exactly these intermediates as jamba/mamba2's dominant memory
term in the pure-JAX formulation.

Grid: (B, nh, S/L).  Block shapes are MXU-aligned for L ∈ {128, 256},
hp ∈ {64, 128}, ds ∈ {16, 128} (the assigned configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(L: int, hp: int, ds: int):
    def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, state_ref):
        ci = pl.program_id(2)
        nc = pl.num_programs(2)

        @pl.when(ci == 0)
        def _init():
            state_ref[...] = jnp.zeros_like(state_ref)

        x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, hp)
        dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
        A = a_ref[0].astype(jnp.float32)  # scalar (negative)
        B = b_ref[0].astype(jnp.float32)  # (L, ds)
        C = c_ref[0].astype(jnp.float32)  # (L, ds)

        a = dt * A  # (L,)
        a_cum = jnp.cumsum(a)  # inclusive
        # L[i, j] = exp(a_cum[i] - a_cum[j]) for j <= i, else 0
        diff = a_cum[:, None] - a_cum[None, :]
        tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1) <= jax.lax.broadcasted_iota(
            jnp.int32, (L, L), 0
        )
        Lmat = jnp.where(tri, jnp.exp(diff), 0.0)

        xd = x * dt[:, None]  # (L, hp)
        scores = (C @ B.T) * Lmat  # (L, L)
        y = scores @ xd  # intra-chunk

        state = state_ref[...]  # (hp, ds)
        y += jnp.exp(a_cum)[:, None] * (C @ state.T)  # inter-chunk

        total = jnp.exp(a_cum[-1])
        decay = jnp.exp(a_cum[-1] - a_cum)  # (L,)
        state_ref[...] = state * total + xd.T @ (B * decay[:, None])

        y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

        @pl.when(ci == nc - 1)
        def _emit_state():
            state_out_ref[0, 0] = state_ref[...]

    return _kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # (B, S, nh, hp)
    dt: jax.Array,  # (B, S, nh) post-softplus
    A: jax.Array,  # (nh,) negative
    Bm: jax.Array,  # (B, S, ds)
    Cm: jax.Array,  # (B, S, ds)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,S,nh,hp) fp32, final_state (B,nh,hp,ds) fp32).

    Matches ``repro.kernels.ref.ssd_scan_ref`` / ``models.ssd.ssd_chunked``.
    """
    Bsz, S, nh, hp = x.shape
    ds = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad dt with zeros => a=0, decay 1, no state contribution
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // L
    grid = (Bsz, nh, nc)
    y, final_state = pl.pallas_call(
        _make_kernel(L, hp, ds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, L, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, L, ds), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hp, ds), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Sp, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nh, hp, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hp, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y[:, :S], final_state
