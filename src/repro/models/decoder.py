"""Unified model assembly for all assigned architectures.

One decoder stack covers dense / MoE / SSM / hybrid / VLM-backbone; whisper
adds an encoder stack + cross-attention.  Layers are grouped into
super-blocks of ``cfg.block_period`` so heterogeneous interleaves (jamba)
still scan with stacked parameters.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssd as ssd_lib
from repro.models.common import (
    Params,
    apply_mlp,
    apply_norm,
    embed_init,
    init_mlp,
    init_norm,
    softmax_cross_entropy,
    softmax_cross_entropy_per_token,
)

# ---------------------------------------------------------------------------
# Activation sharding constraints (set by the launcher; None on single host).
# GSPMD's solver, left alone with FSDP-sharded weights, propagates the d-dim
# sharding INTO the activations and replicates the batch — every layer then
# all-reduces (B_full, S, d) partials (EXPERIMENTS.md §Perf iteration 3).
# Pinning activations to batch-sharded layout forces the intended
# weight-gather FSDP semantics instead.
# ---------------------------------------------------------------------------

_ACT_SHARDING = None  # NamedSharding for (B, S, d) activations
_LOGITS_SHARDING = None  # NamedSharding for (B, S, V) logits


def set_activation_shardings(act=None, logits=None) -> None:
    global _ACT_SHARDING, _LOGITS_SHARDING
    _ACT_SHARDING = act
    _LOGITS_SHARDING = logits


def _constrain(x: jax.Array, which: str = "act") -> jax.Array:
    ns = _ACT_SHARDING if which == "act" else _LOGITS_SHARDING
    if ns is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, ns)
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: ModelConfig, i: int, dtype, cross: bool = False) -> Params:
    kind = cfg.layer_kind(i)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind == "attn":
        p["attn"] = attn_lib.init_attn(ks[0], cfg, dtype)
    else:
        p["ssm"] = ssd_lib.init_ssd(ks[0], cfg, dtype)
    if cross:
        p["norm_cross"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["cross"] = attn_lib.init_attn(ks[2], cfg, dtype, cross=True)
    if cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if cfg.layer_moe(i):
            p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _stack_blocks(cfg: ModelConfig, key: jax.Array, dtype, cross: bool = False):
    """Returns a tuple (len=block_period) of pytrees, leaves stacked over n_blocks."""
    period = cfg.block_period
    L = cfg.num_layers
    assert L % period == 0, (cfg.name, L, period)
    n_blocks = L // period
    keys = jax.random.split(key, L).reshape(n_blocks, period, -1)
    positions = []
    for j in range(period):
        per_block = [_init_layer(keys[b, j], cfg, b * period + j, dtype, cross) for b in range(n_blocks)]
        positions.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_block))
    return tuple(positions)


def init_params(cfg: ModelConfig, key: jax.Array, max_seq: int = 4096) -> Params:
    dtype = cfg.dtype
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "blocks": _stack_blocks(cfg, ks[1], dtype, cross=cfg.is_encoder_decoder),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype)
    needs_pos = (not cfg.use_rope) and cfg.layer_kind(0) != "ssm" and any(
        cfg.layer_kind(i) == "attn" for i in range(cfg.num_layers)
    )
    if cfg.is_encoder_decoder or (needs_pos and cfg.family != "hybrid"):
        # learned absolute positions (whisper); jamba uses none at all
        if cfg.is_encoder_decoder:
            p["pos_embed"] = embed_init(ks[3], max_seq, cfg.d_model, dtype)
        else:
            p["pos_embed"] = embed_init(ks[3], max_seq, cfg.d_model, dtype)
    if cfg.is_encoder_decoder:
        import dataclasses

        enc_stack_cfg = dataclasses.replace(cfg, num_layers=cfg.num_encoder_layers, num_experts=0)
        p["enc_blocks"] = _stack_blocks(enc_stack_cfg, ks[4], dtype, cross=False)
        p["enc_pos_embed"] = embed_init(ks[5], cfg.encoder_seq, cfg.d_model, dtype)
        p["enc_final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    return p


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_layer_fwd(
    cfg: ModelConfig,
    p: Params,
    i_in_block: int,
    x: jax.Array,
    positions: jax.Array,
    window: int,
    causal: bool,
    encoder_out: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    kind = cfg.layer_kind(i_in_block)
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        a = attn_lib.attn_forward(cfg, p["attn"], h, positions, causal=causal, window=window)
    else:
        a = ssd_lib.ssd_forward(cfg, p["ssm"], h)
    x = x + a
    if encoder_out is not None and "cross" in p:
        h = apply_norm(cfg.norm, p["norm_cross"], x, cfg.norm_eps)
        x = x + attn_lib.attn_forward(cfg, p["cross"], h, positions, encoder_out=encoder_out)
    if cfg.d_ff > 0:
        h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        if cfg.layer_moe(i_in_block):
            f, aux = moe_lib.apply_moe(cfg, p["moe"], h)
        else:
            f = apply_mlp(p["mlp"], h, cfg.act)
        x = x + f
    return x, aux


def _run_stack(
    cfg: ModelConfig,
    blocks,
    x: jax.Array,
    positions: jax.Array,
    window: int,
    causal: bool,
    encoder_out: Optional[jax.Array] = None,
    remat: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    period = len(blocks)

    def body(carry, block_params):
        x, aux = carry
        for j in range(period):
            x, a = _apply_layer_fwd(cfg, block_params[j], j, x, positions, window, causal, encoder_out)
            x = _constrain(x)
            aux = aux + a
        return (x, aux), None

    if remat:  # activation checkpointing at super-block granularity
        body = jax.checkpoint(body)
    carry = (x, jnp.zeros((), jnp.float32))
    if unroll:
        # python loop — identical math; used by the dry-run so that XLA
        # cost_analysis sees every layer (while-loop bodies are counted once)
        n_blocks = jax.tree.leaves(blocks)[0].shape[0]
        for b in range(n_blocks):
            blk = jax.tree.map(lambda l: l[b], blocks)
            carry, _ = body(carry, blk)
        return carry
    (x, aux), _ = jax.lax.scan(body, carry, blocks)
    return x, aux


def _encode(cfg: ModelConfig, params: Params, frames: jax.Array, unroll: bool = False) -> jax.Array:
    """Whisper encoder over stubbed conv-frontend frames (B, S_enc, d)."""
    S = frames.shape[1]
    x = frames + params["enc_pos_embed"][None, :S, :]
    pos = jnp.arange(S)
    x, _ = _run_stack(cfg, params["enc_blocks"], x, pos, window=0, causal=False, unroll=unroll)
    return apply_norm(cfg.norm, params["enc_final_norm"], x, cfg.norm_eps)


def forward_logits(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    prefix_embeddings: Optional[jax.Array] = None,
    encoder_frames: Optional[jax.Array] = None,
    window: int = 0,
    remat: bool = False,
    last_only: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits over token positions (B, S, V), moe aux loss).

    ``last_only=True`` (serving prefill) computes logits for the final
    position only — a (B, S, V) logits tensor at 32k prefill would dwarf the
    activations."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    P = 0
    if prefix_embeddings is not None:
        P = prefix_embeddings.shape[1]
        x = jnp.concatenate([prefix_embeddings.astype(cfg.dtype), x], axis=1)
    if "pos_embed" in params:
        x = x + params["pos_embed"][None, : S + P, :].astype(cfg.dtype)
    x = _constrain(x)
    positions = jnp.arange(S + P)
    encoder_out = None
    if cfg.is_encoder_decoder:
        assert encoder_frames is not None
        encoder_out = _encode(cfg, params, encoder_frames, unroll=unroll)
    eff_window = window if window > 0 else cfg.sliding_window
    x, aux = _run_stack(
        cfg, params["blocks"], x, positions, eff_window, True, encoder_out, remat, unroll
    )
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    if P:
        x = x[:, P:, :]
    if last_only:
        x = x[:, -1:, :]
    x = _constrain(x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = _constrain(x @ head.T.astype(cfg.dtype), "logits")
    return logits, aux


def forward_hidden(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    prefix_embeddings: Optional[jax.Array] = None,
    encoder_frames: Optional[jax.Array] = None,
    remat: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Final-norm hidden states (B, S_tokens, d) + moe aux — the pre-head
    tensor used by the chunked-CE loss (§Perf iteration 6)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    P = 0
    if prefix_embeddings is not None:
        P = prefix_embeddings.shape[1]
        x = jnp.concatenate([prefix_embeddings.astype(cfg.dtype), x], axis=1)
    if "pos_embed" in params:
        x = x + params["pos_embed"][None, : S + P, :].astype(cfg.dtype)
    x = _constrain(x)
    positions = jnp.arange(S + P)
    encoder_out = None
    if cfg.is_encoder_decoder:
        assert encoder_frames is not None
        encoder_out = _encode(cfg, params, encoder_frames, unroll=unroll)
    eff_window = cfg.sliding_window
    x, aux = _run_stack(
        cfg, params["blocks"], x, positions, eff_window, True, encoder_out, remat, unroll
    )
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    if P:
        x = x[:, P:, :]
    return _constrain(x), aux


def loss_fn(
    cfg: ModelConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    aux_weight: float = 0.01,
    remat: bool = False,
    unroll: bool = False,
    ce_impl: str = "gather",
    ce_chunk: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """``ce_chunk > 0``: the LM head + CE are evaluated in rematted sequence
    chunks so the (B, S, V) logits (and their fp32 shadows) never exist in
    full — per-chunk logits are recomputed in the backward pass."""
    if ce_chunk > 0:
        x, aux = forward_hidden(
            cfg,
            params,
            batch["tokens"],
            prefix_embeddings=batch.get("prefix_embeddings"),
            encoder_frames=batch.get("encoder_frames"),
            remat=remat,
            unroll=unroll,
        )
        head = (params["embed"] if cfg.tie_embeddings else params["lm_head"]).T.astype(cfg.dtype)
        xs = x[:, :-1]
        ls = batch["labels"][:, 1:]
        B, Sm1, d = xs.shape
        C = ce_chunk
        pad = (-Sm1) % C
        if pad:  # pad with a repeat of the last column, weight it zero
            xs = jnp.concatenate([xs, jnp.repeat(xs[:, -1:], pad, 1)], axis=1)
            ls = jnp.concatenate([ls, jnp.repeat(ls[:, -1:], pad, 1)], axis=1)
        w = jnp.concatenate([jnp.ones((Sm1,)), jnp.zeros((pad,))])
        nch = (Sm1 + pad) // C
        xc = xs.reshape(B, nch, C, d).transpose(1, 0, 2, 3)
        lc = ls.reshape(B, nch, C).transpose(1, 0, 2)
        wc = w.reshape(nch, C)

        @jax.checkpoint
        def chunk_ce(args):
            xi, li, wi = args
            logits = _constrain(xi @ head, "logits")
            per_tok = softmax_cross_entropy_per_token(logits, li, impl=ce_impl)
            return jnp.sum(per_tok * wi[None, :])

        if unroll:  # analysis-grade: every chunk visible to cost_analysis
            ce_sum = jnp.zeros((), jnp.float32)
            for i in range(nch):
                ce_sum = ce_sum + chunk_ce((xc[i], lc[i], wc[i]))
            ce = ce_sum / (B * Sm1)
        else:
            totals = jax.lax.map(chunk_ce, (xc, lc, wc))
            ce = jnp.sum(totals) / (B * Sm1)
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "moe_aux": aux}
    logits, aux = forward_logits(
        cfg,
        params,
        batch["tokens"],
        prefix_embeddings=batch.get("prefix_embeddings"),
        encoder_frames=batch.get("encoder_frames"),
        remat=remat,
        unroll=unroll,
    )
    ce = softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:], impl=ce_impl)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Feature tap (the paper's proxy, at modern scale)
# ---------------------------------------------------------------------------


def feature_vector(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    prefix_embeddings: Optional[jax.Array] = None,
    encoder_frames: Optional[jax.Array] = None,
) -> jax.Array:
    """Mean-pooled final hidden state over a small batch — the intermediate-
    layer feature vector z of Eq. (5)/(6), one forward pass, no backward."""
    logits, _ = forward_logits(cfg, params, tokens, prefix_embeddings, encoder_frames)
    # The paper taps the output layer (10-dim for CIFAR). For LMs we tap the
    # softmax-normalized output distribution averaged over positions+batch.
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.mean(probs, axis=(0, 1))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig, batch: int, length: int, rolling: bool = False, cross_cache: bool = False
) -> Tuple:
    """Per-block-position caches, leaves stacked over n_blocks. ``length`` is
    the KV capacity (the rolling window width when rolling=True).
    ``cross_cache=True`` (enc-dec) adds ck/cv planes for prefill_cross_cache."""
    period = cfg.block_period
    n_blocks = cfg.num_layers // period
    dtype = cfg.dtype
    caches = []
    for j in range(period):
        kind = cfg.layer_kind(j)
        if kind == "attn":
            W = min(length, cfg.sliding_window) if (cfg.sliding_window and not rolling) else length
            one = attn_lib.init_kv_cache(cfg, batch, W, dtype)
        else:
            one = ssd_lib.init_ssd_cache(cfg, batch, dtype)
        if cfg.is_encoder_decoder and cross_cache:
            # cross-attention K/V cached once at prefill (beyond-paper
            # serving optimization — EXPERIMENTS.md §Perf iteration 7)
            nkv, hd = cfg.num_kv_heads, cfg.head_dim
            one = dict(one)
            one["ck"] = jnp.zeros((batch, cfg.encoder_seq, nkv, hd), dtype)
            one["cv"] = jnp.zeros((batch, cfg.encoder_seq, nkv, hd), dtype)
        caches.append(jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_blocks,) + x.shape), one))
    return tuple(caches)


def prefill_cross_cache(cfg: ModelConfig, params: Params, cache: Tuple, encoder_out: jax.Array) -> Tuple:
    """Fill the cross-attention K/V planes of a fresh cache from the encoder
    output (once per request, before decoding)."""
    assert cfg.is_encoder_decoder
    period = len(params["blocks"])
    new = []
    for j in range(period):

        def fill(block_p, block_c):
            ck, cv = attn_lib.cross_kv(cfg, block_p["cross"], encoder_out)
            c = dict(block_c)
            c["ck"], c["cv"] = ck, cv
            return c

        new.append(jax.vmap(fill)(params["blocks"][j], cache[j]))
    return tuple(new)


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Tuple,
    tokens: jax.Array,
    positions: jax.Array,
    rolling: bool = False,
    encoder_out: Optional[jax.Array] = None,
    unroll: bool = False,
) -> Tuple[jax.Array, Tuple]:
    """One-token decode. tokens (B,1), positions (B,) -> (logits (B,1,V), cache)."""
    x = params["embed"][tokens].astype(cfg.dtype)
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions][:, None, :].astype(cfg.dtype)
    period = len(params["blocks"])

    def body(x, scanned):
        block_params, block_cache = scanned
        new_cache = []
        for j in range(period):
            p = block_params[j]
            c = block_cache[j]
            kind = cfg.layer_kind(j)
            h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
            cross_planes = {k_: c[k_] for k_ in ("ck", "cv") if k_ in c}
            if kind == "attn":
                roll = rolling or (cfg.sliding_window > 0)
                a, c = attn_lib.attn_decode(cfg, p["attn"], h, c, positions, rolling=roll)
            else:
                a, c = ssd_lib.ssd_decode(cfg, p["ssm"], h, c)
            if cross_planes:  # keep the (static) cross K/V planes in the carry
                c = {**c, **cross_planes}
            x = x + a
            if "cross" in p and (encoder_out is not None or "ck" in c):
                h = apply_norm(cfg.norm, p["norm_cross"], x, cfg.norm_eps)
                if "ck" in c:  # cached cross K/V (no per-token re-projection)
                    ca = attn_lib.cross_decode_cached(cfg, p["cross"], h, c["ck"], c["cv"])
                else:
                    ca, _ = attn_lib.attn_decode(cfg, p["cross"], h, c, positions, encoder_out=encoder_out)
                x = x + ca
            if cfg.d_ff > 0:
                h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
                if cfg.layer_moe(j):
                    f, _ = moe_lib.apply_moe(cfg, p["moe"], h)
                else:
                    f = apply_mlp(p["mlp"], h, cfg.act)
                x = x + f
            new_cache.append(c)
        return x, tuple(new_cache)

    if unroll:
        n_blocks = jax.tree.leaves(cache)[0].shape[0]
        ys = []
        for b in range(n_blocks):
            blk = jax.tree.map(lambda l: l[b], params["blocks"])
            cb = jax.tree.map(lambda l: l[b], cache)
            x, cb_new = body(x, (blk, cb))
            ys.append(cb_new)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    else:
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.T.astype(cfg.dtype)
    return logits, new_cache
