from repro.fl.backend import cnn_backend, lm_backend  # noqa: F401
