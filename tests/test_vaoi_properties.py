"""Property-based tests (hypothesis) on the VAoI metric — Eq. (2)/(7)
invariants from the paper."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra.numpy import arrays

from repro.core.vaoi import feature_distance, select_topk, vaoi_update

ages = arrays(np.float32, st.integers(1, 64), elements=st.floats(0, 1000, width=32))


@given(
    age=ages,
    mu=st.floats(0.0, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=50, deadline=None)
def test_vaoi_update_invariants(age, mu, seed):
    n = age.shape[0]
    rng = np.random.RandomState(seed)
    m = rng.exponential(1.0, n).astype(np.float32)
    q = (rng.rand(n) < 0.5).astype(np.float32)
    new = np.asarray(vaoi_update(jnp.asarray(age), jnp.asarray(m), jnp.asarray(q), mu))
    # (1) participation resets the age to exactly zero
    assert np.all(new[q == 1.0] == 0.0)
    # (2) ages never go negative
    assert np.all(new >= 0.0)
    # (3) non-participants: age grows by exactly 1 iff M >= mu, else unchanged
    np_mask = q == 0.0
    expected = np.where(m >= mu, age + 1.0, age)
    assert np.allclose(new[np_mask], expected[np_mask])
    # (4) growth is bounded by +1 per round
    assert np.all(new <= age + 1.0)


@given(
    n=st.integers(2, 64),
    k_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_select_topk_properties(n, k_frac, seed):
    k = max(1, int(n * k_frac))
    rng = np.random.RandomState(seed)
    age = jnp.asarray(rng.exponential(5.0, n).astype(np.float32))
    sel = np.asarray(select_topk(age, k, jax.random.PRNGKey(seed)))
    # exactly k selected
    assert sel.sum() == k
    # selection respects ordering up to the 1e-3 tie-break noise:
    # every selected client's age >= every unselected client's age - epsilon
    if k < n:
        min_sel = float(np.asarray(age)[sel].min())
        max_unsel = float(np.asarray(age)[~sel].max())
        total = float(np.asarray(age).sum())
        eps = 1e-3 * max(total, 1.0) + 1e-6
        assert min_sel >= max_unsel - eps


@given(
    nf=st.tuples(st.integers(1, 32), st.integers(1, 64)),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_feature_distance_is_a_metric(nf, seed):
    n, f = nf
    rng = np.random.RandomState(seed)
    v = jnp.asarray(rng.randn(n, f).astype(np.float32))
    h = jnp.asarray(rng.randn(n, f).astype(np.float32))
    d_vh = np.asarray(feature_distance(v, h))
    d_hv = np.asarray(feature_distance(h, v))
    d_vv = np.asarray(feature_distance(v, v))
    assert np.all(d_vh >= 0)
    assert np.allclose(d_vh, d_hv, rtol=1e-6)  # symmetry
    assert np.allclose(d_vv, 0.0, atol=1e-6)  # identity
    # triangle inequality through a third point
    w = jnp.asarray(rng.randn(n, f).astype(np.float32))
    d_vw = np.asarray(feature_distance(v, w))
    d_wh = np.asarray(feature_distance(w, h))
    assert np.all(d_vh <= d_vw + d_wh + 1e-4)


def test_vaoi_cold_start_uniformity():
    """All-zero ages (t=0): selection must still return exactly k clients."""
    age = jnp.zeros((50,))
    seen = set()
    for s in range(20):
        sel = np.asarray(select_topk(age, 5, jax.random.PRNGKey(s)))
        assert sel.sum() == 5
        seen.update(np.nonzero(sel)[0].tolist())
    # random tie-breaking explores different clients across keys
    assert len(seen) > 10


def test_select_gumbel_properties():
    """Stochastic selection: exactly k chosen; frequency tracks age mass."""
    import numpy as np
    from repro.core.vaoi import select_gumbel

    age = jnp.asarray([10.0, 10.0, 10.0, 0.1, 0.1, 0.1, 0.1, 0.1])
    counts = np.zeros(8)
    for s in range(200):
        sel = np.asarray(select_gumbel(age, 2, jax.random.PRNGKey(s)))
        assert sel.sum() == 2
        counts += sel
    # the three heavy clients should dominate the selections
    assert counts[:3].sum() > counts[3:].sum()
