"""Property-based tests on the energy-harvesting substrate — §III-C
invariants: causality, battery bounds, accounting conservation."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import energy as energy_lib


def _run(n, S, kappa, p_bc, e_max, seed, want_all=True, battery0=None):
    key = jax.random.PRNGKey(seed)
    st0 = energy_lib.SlotState(
        battery=jnp.zeros((n,), jnp.int32) if battery0 is None else battery0,
        started=jnp.zeros((n,), bool),
        start_slot=jnp.full((n,), S, jnp.int32),
        pending=jnp.zeros((n,), bool),
        uploaded=jnp.zeros((n,), bool),
        counter=jnp.zeros((n,), jnp.int32),
        energy_used=jnp.zeros((n,), jnp.int32),
        key=key,
    )
    want = (lambda s, st: jnp.ones((n,), bool)) if want_all else (lambda s, st: jnp.zeros((n,), bool))
    return energy_lib.scan_epoch(
        st0, S=S, kappa=kappa, p_bc=p_bc, e_max=e_max, want_fn=want
    )


@given(
    n=st.integers(1, 32),
    S=st.integers(5, 60),
    kappa=st.integers(1, 25),
    p_bc=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_energy_invariants(n, S, kappa, p_bc, seed):
    if kappa > S:
        kappa = S
    e_max = kappa + 5
    st_out = _run(n, S, kappa, p_bc, seed=seed, e_max=e_max)
    battery = np.asarray(st_out.battery)
    used = np.asarray(st_out.energy_used)
    started = np.asarray(st_out.started)
    # battery within [0, e_max]
    assert np.all(battery >= 0) and np.all(battery <= e_max)
    # strict causality: total use <= total harvest (initial battery = 0), so
    # battery = harvested - used >= 0 also implies used <= S (max harvest)
    assert np.all(used <= S)
    # a client that started paid at least kappa
    assert np.all(used[started] >= kappa)
    # a client that never started and never transmitted paid nothing
    idle = ~started & ~st_out.uploaded & ~np.asarray(st_out.pending)
    assert np.all(used[np.asarray(idle)] == 0)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_no_harvest_no_action(seed):
    """p_bc = 0, battery 0: nothing can ever start (energy causality)."""
    st_out = _run(8, 30, 20, 0.0, e_max=25, seed=seed)
    assert not np.any(np.asarray(st_out.started))
    assert np.all(np.asarray(st_out.energy_used) == 0)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_guaranteed_harvest_trains(seed):
    """p_bc = 1: with S >= 2*kappa every willing client trains and uploads."""
    S, kappa = 45, 20
    st_out = _run(8, S, kappa, 1.0, e_max=kappa + 5, seed=seed)
    assert np.all(np.asarray(st_out.started))
    assert np.all(np.asarray(st_out.uploaded))
    # exactly kappa (training) + 1 (upload) units consumed
    assert np.all(np.asarray(st_out.energy_used) == kappa + 1)


@given(
    seed=st.integers(0, 2**31 - 1),
    kappa=st.integers(2, 20),
)
@settings(max_examples=20, deadline=None)
def test_deadline_respected(seed, kappa):
    """No training may start after slot S - kappa (completes within epoch)."""
    S = 30
    if kappa > S:
        return
    st_out = _run(16, S, kappa, 1.0, e_max=kappa + 5, seed=seed)
    starts = np.asarray(st_out.start_slot)
    started = np.asarray(st_out.started)
    assert np.all(starts[started] <= S - kappa)
