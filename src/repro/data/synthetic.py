"""Synthetic data pipeline.

CIFAR-10 is not available offline, so we generate a class-conditional
Gaussian image dataset with the same geometry (32x32x3, 10 classes) and
partition it across clients with a Dirichlet(alpha) label distribution —
exactly the paper's non-IID protocol (§V).  Smaller alpha => more skew.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def make_class_prototypes(key: jax.Array, num_classes: int, image_size: int, channels: int) -> jax.Array:
    """Smooth per-class prototype images (low-frequency patterns so a CNN can learn)."""
    k1, k2 = jax.random.split(key)
    coarse = jax.random.normal(k1, (num_classes, 8, 8, channels)) * 1.5
    protos = jax.image.resize(coarse, (num_classes, image_size, image_size, channels), "bilinear")
    return protos


def dirichlet_label_partition(
    key: jax.Array, num_clients: int, samples_per_client: int, num_classes: int, alpha: float
) -> jax.Array:
    """Per-client label arrays (N, n) sampled from client-specific Dir(alpha) mixtures."""
    k1, k2 = jax.random.split(key)
    props = jax.random.dirichlet(k1, jnp.full((num_classes,), alpha), (num_clients,))  # (N, C)
    labels = jax.vmap(
        lambda k, p: jax.random.choice(k, num_classes, (samples_per_client,), p=p)
    )(jax.random.split(k2, num_clients), props)
    return labels.astype(jnp.int32)


def make_federated_dataset(
    key: jax.Array,
    num_clients: int = 100,
    samples_per_client: int = 300,
    num_classes: int = 10,
    image_size: int = 32,
    channels: int = 3,
    alpha: float = 0.1,
    test_size: int = 1000,
    noise: float = 0.8,
) -> Dict[str, jax.Array]:
    """Returns dict with client images (N, n, H, W, C), labels (N, n),
    plus a balanced global test set."""
    kp, kl, kx, kt = jax.random.split(key, 4)
    protos = make_class_prototypes(kp, num_classes, image_size, channels)
    labels = dirichlet_label_partition(kl, num_clients, samples_per_client, num_classes, alpha)
    eps = jax.random.normal(kx, (num_clients, samples_per_client, image_size, image_size, channels))
    images = protos[labels] + noise * eps
    test_labels = (jnp.arange(test_size) % num_classes).astype(jnp.int32)
    test_eps = jax.random.normal(kt, (test_size, image_size, image_size, channels))
    test_images = protos[test_labels] + noise * test_eps
    return {
        "images": images,
        "labels": labels,
        "test_images": test_images,
        "test_labels": test_labels,
    }


def make_token_dataset(
    key: jax.Array,
    num_clients: int,
    samples_per_client: int,
    seq_len: int,
    vocab_size: int,
    alpha: float = 0.5,
    num_topics: int = 16,
) -> Dict[str, jax.Array]:
    """Synthetic non-IID LM data: each client mixes vocab 'topics' with
    Dirichlet(alpha) weights — used by the at-scale FL examples."""
    k1, k2, k3 = jax.random.split(key, 3)
    topic_of_token = jax.random.randint(k1, (vocab_size,), 0, num_topics)
    client_topic = jax.random.dirichlet(k2, jnp.full((num_topics,), alpha), (num_clients,))
    token_probs = client_topic[:, topic_of_token]  # (N, V)
    token_probs = token_probs / jnp.sum(token_probs, axis=-1, keepdims=True)
    keys = jax.random.split(k3, num_clients)
    tokens = jax.vmap(
        lambda k, p: jax.random.choice(k, vocab_size, (samples_per_client, seq_len), p=p)
    )(keys, token_probs)
    return {"tokens": tokens.astype(jnp.int32)}
