"""Client-sharded fleet simulator — Alg. 1 with the N axis sharded over the
mesh's ``data`` axis (DESIGN.md §9).

``run_simulation`` keeps every per-client array on one device; at fleet scale
``msg_params`` alone is N full model copies.  :func:`run_fleet` runs the SAME
``simulator.epoch_body`` under ``shard_map``: the global model and PRNG key
stay replicated, while ``msg_params``, ``h``, ``age``, ``battery``,
``pending``, ``counter``, ``retries``, ``backoff``, the client datasets, and
the per-client harvest, data-stream, and uplink-channel state live on their
shard of the fleet.  Only the
:class:`EpochOps` points differ from the solo path:

  * Alg. 2 selection — distributed top-k (``vaoi.select_topk_sharded``):
    local top-k per shard, all-gather the (score, index) candidate pairs,
    global top-k over candidates;
  * per-client training keys — this shard's slice of the global key split;
  * FedAvg — a ``psum`` of masked per-shard sums and counts
    (``kernels/fedavg_reduce`` as the per-shard reducer under
    ``use_kernel=True``); under active-set compaction (DESIGN.md §11) the
    per-shard sums come from each shard's local ``min(cap, N_loc)``
    training slab plus its old-carrier uploads;
  * metrics — ``psum`` scalar reductions.

Correctness contract (tested in ``tests/test_fleet.py``): for any N
divisible by the shard count, a fleet run matches the single-device
``run_simulation`` — integer slot dynamics (batteries, uploads, starts) and
VAoI ages exactly, float trajectories (f1, avg_m) to fp32 rounding.  The
exactness recipe is global-draw-and-slice: every random draw keeps its
single-device shape, computed from the replicated key on each shard, and the
shard slices its own window (see ``harvest.make_sharded_process``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import channel as channel_lib
from repro.core import harvest as harvest_lib
from repro.core import policies as policy_lib
from repro.data import stream as stream_lib
from repro.core.simulator import (
    Backend,
    EHFLConfig,
    EpochCarry,
    EpochOps,
    _compact_mean,
    _masked_mean,
    _masked_mean_kernel,
    drive_epochs,
    epoch_body,
    init_carry,
)

AXIS = "data"  # the client/fleet mesh axis


def fleet_ops(cfg: EHFLConfig, use_kernel: bool = False, axis_name: str = AXIS) -> EpochOps:
    """The distributed :class:`EpochOps`: selection, training keys, FedAvg,
    and metric reductions over a client-sharded fleet.  FedAvg is the SAME
    ``_masked_mean``/``_masked_mean_kernel`` as the solo path with a psum
    ``reduce_sum`` hook — masked per-shard sums and counts, psum'd."""
    N = cfg.num_clients
    psum = lambda x: jax.lax.psum(x, axis_name)
    agg = _masked_mean_kernel if use_kernel else _masked_mean

    def select(spec, age, t, k, key):
        return policy_lib.epoch_selection_sharded(
            spec, age, t, k, key, axis_name=axis_name, n_global=N
        )

    def train_keys(k_train, n_loc):
        return jax.lax.dynamic_slice_in_dim(
            jax.random.split(k_train, N), jax.lax.axis_index(axis_name) * n_loc, n_loc
        )

    return EpochOps(
        select=select,
        train_keys=train_keys,
        masked_mean=lambda contrib, mask, fb: agg(contrib, mask, fb, reduce_sum=psum),
        reduce_sum=lambda x: psum(jnp.sum(x)),
        # compaction is per-shard (each shard gathers its own starters into
        # a min(cap, N_loc) slab — DESIGN.md §11); aggregation stays a psum
        # of slab partial sums + old-carrier partial sums
        compact_mean=lambda slab, sm, old, om, fb: _compact_mean(
            slab, sm, old, om, fb, reduce_sum=psum, use_kernel=use_kernel
        ),
    )


def make_fleet_epoch_fn(
    cfg: EHFLConfig,
    backend: Backend,
    use_kernel: bool = False,
    axis_name: str = AXIS,
) -> Callable:
    """The ``shard_map``-interior counterpart of ``simulator.make_epoch_fn``:
    the same ``epoch_body`` with :func:`fleet_ops` and the sharded harvest
    process, as a pure ``(carry, t, images, labels) -> (carry, metrics)``
    over the LOCAL client shard."""
    spec = policy_lib.make_policy(
        cfg.policy, num_clients=cfg.num_clients, k=cfg.k, num_groups=cfg.num_groups
    )
    process = harvest_lib.make_sharded_process(
        cfg.harvest, p_bc=cfg.p_bc, axis_name=axis_name, n_global=cfg.num_clients,
        **dict(cfg.harvest_params),
    )
    stream_params = dict(cfg.stream_params)
    if cfg.stream in stream_lib.CLASS_CONDITIONED:
        # same backend-derived class count as the solo path (init_carry
        # builds the solo state the sharded step must be shape-compatible with)
        stream_params.setdefault("num_classes", backend.num_classes)
    stream = stream_lib.make_sharded_stream(
        cfg.stream, axis_name=axis_name, n_global=cfg.num_clients,
        **stream_params,
    )
    chan = channel_lib.make_sharded_channel(
        cfg.channel, axis_name=axis_name, n_global=cfg.num_clients,
        **dict(cfg.channel_params),
    )
    ops = fleet_ops(cfg, use_kernel, axis_name)
    return lambda carry, t, images, labels: epoch_body(
        carry, t, images, labels,
        cfg=cfg, backend=backend, spec=spec, process=process, ops=ops,
        stream=stream, channel=chan, use_kernel=use_kernel,
    )


def _carry_pspecs(cfg: EHFLConfig, carry_struct: EpochCarry) -> EpochCarry:
    """PartitionSpec tree for an :class:`EpochCarry`: client-axis leaves
    sharded over the fleet axis, global model + keys replicated (the
    scheduler-state rule of ``launch/sharding.py``)."""
    cl, rep = P(AXIS), P()
    hspec = None
    if carry_struct.harvest is not None:
        flags = harvest_lib.state_sharding_tree(cfg.harvest)
        hspec = jax.tree.map(lambda f: cl if f else rep, flags)
    sspec = None
    if carry_struct.stream is not None:
        sflags = stream_lib.state_sharding_tree(cfg.stream)
        sspec = jax.tree.map(lambda f: cl if f else rep, sflags)
    cspec = None
    if carry_struct.channel is not None:
        cflags = channel_lib.state_sharding_tree(cfg.channel)
        cspec = jax.tree.map(lambda f: cl if f else rep, cflags)
    return EpochCarry(
        global_params=jax.tree.map(lambda _: rep, carry_struct.global_params),
        msg_params=jax.tree.map(lambda _: cl, carry_struct.msg_params),
        h=cl, age=cl, battery=cl, pending=cl, counter=cl, key=rep,
        harvest=hspec,
        stream=sspec,
        retries=cl, backoff=cl,
        channel=cspec,
    )


def fleet_program(
    cfg: EHFLConfig,
    backend: Backend,
    data: Dict[str, jax.Array],
    *,
    mesh: Mesh | None = None,
    use_kernel: bool = False,
) -> Tuple[EpochCarry, Callable, Dict[str, jax.Array], Mesh]:
    """Build the sharded fleet program: the initial carry (born sharded —
    ``init_carry`` is jitted with sharded out_shardings, so the N model
    copies of ``msg_params`` never materialize on one device), the jitted
    ``scan_chunk(carry, ts, images, labels)``, the sharded client data, and
    the mesh.  ``run_fleet`` drives it; ``benchmarks/fleet_bench`` times it.
    """
    if mesh is None:
        # core->launch is a deliberate lazy import: mesh construction lives
        # with the other launch-layer topology code (DESIGN.md §1)
        from repro.launch.mesh import make_fleet_mesh

        mesh = make_fleet_mesh(num_clients=cfg.num_clients)
    if AXIS not in mesh.axis_names:
        raise ValueError(f"fleet mesh needs a {AXIS!r} axis; got {mesh.axis_names}")
    shards = mesh.shape[AXIS]
    if cfg.num_clients % shards:
        raise ValueError(
            f"num_clients={cfg.num_clients} must divide over {shards} shards"
        )

    epoch_fn = make_fleet_epoch_fn(cfg, backend, use_kernel=use_kernel)
    carry_struct = jax.eval_shape(lambda: init_carry(cfg, backend))
    specs = _carry_pspecs(cfg, carry_struct)
    cl, rep = P(AXIS), P()

    # PartitionSpec is a tuple subclass: an explicit is_leaf keeps tree.map
    # from descending into the specs themselves
    carry_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    carry0 = jax.jit(
        lambda: init_carry(cfg, backend), out_shardings=carry_shardings
    )()

    # the carry is donated (its msg_params shard is still N_loc model
    # copies per device); the data/ts args are reused across eval_every
    # chunks, so they are deliberately NOT donated
    scan_chunk = jax.jit(
        shard_map(
            lambda c, ts, images, labels: jax.lax.scan(
                lambda cc, t: epoch_fn(cc, t, images, labels), c, ts
            ),
            mesh=mesh,
            in_specs=(specs, rep, cl, cl),
            out_specs=(specs, rep),
            check_rep=False,
        ),
        donate_argnums=(0,),
    )

    cl_sharding = NamedSharding(mesh, cl)
    sharded_data = {
        "images": jax.device_put(data["images"], cl_sharding),
        "labels": jax.device_put(data["labels"], cl_sharding),
    }
    return carry0, scan_chunk, sharded_data, mesh


def run_fleet(
    cfg: EHFLConfig,
    backend: Backend,
    data: Dict[str, jax.Array],
    *,
    mesh: Mesh | None = None,
    use_kernel: bool = False,
) -> Dict[str, Any]:
    """Run T epochs of Alg. 1 with the client axis sharded over the mesh.
    Same return contract as ``run_simulation`` (metric trajectories + final
    model + carry), plus ``num_shards``."""
    carry, scan_chunk, sharded_data, mesh = fleet_program(
        cfg, backend, data, mesh=mesh, use_kernel=use_kernel
    )
    out = drive_epochs(
        lambda c, ts: scan_chunk(c, ts, sharded_data["images"], sharded_data["labels"]),
        carry, cfg, backend, data,
    )
    out["num_shards"] = mesh.shape[AXIS]
    return out
