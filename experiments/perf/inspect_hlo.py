import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, collections
sys.path.insert(0, "src")
import jax
from repro.launch.dryrun import run_one
import repro.launch.dryrun as dr
from repro.configs import get_config, INPUT_SHAPES, input_specs
from repro.launch import sharding, steps
from repro.launch.mesh import make_production_mesh
from repro.models import decoder

arch, shape_name = sys.argv[1], sys.argv[2]
ce = sys.argv[3] if len(sys.argv) > 3 else "gather"
emb = sys.argv[4] if len(sys.argv) > 4 else None
cfg = get_config(arch)
shape = INPUT_SHAPES[shape_name]
mesh = make_production_mesh()
key = jax.random.PRNGKey(0)
params_shape = jax.eval_shape(lambda: decoder.init_params(cfg, key, max_seq=shape.seq_len))
p_shard = sharding.params_shardings(params_shape, mesh, "fsdp", emb)
p_abs = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), params_shape, p_shard)
specs = input_specs(cfg, shape)
in_shard = sharding.input_shardings(specs, mesh)
batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=in_shard[k]) for k, v in specs.items()}
step = steps.make_train_step(cfg, remat=True, ce_impl=ce)
lowered = jax.jit(step, out_shardings=(sharding.replicated(mesh), p_shard)).lower(p_abs, batch_abs)
compiled = lowered.compile()
hlo = compiled.as_text()

DT = {"bf16":2,"f32":4,"f16":2,"s32":4,"u32":4,"pred":1,"s8":1}
rows = []
for line in hlo.splitlines():
    m = re.search(r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(", line)
    if not m: continue
    dt, dims, op = m.group(1), m.group(2), m.group(3)
    n = 1
    for d in dims.split(","):
        if d: n *= int(d)
    size = n * DT.get(dt, 4)
    meta = re.search(r'op_name="([^"]+)"', line)
    rows.append((size, op, f"{dt}[{dims}]", (meta.group(1) if meta else "?")[:110]))
rows.sort(reverse=True)
for size, op, shp, meta in rows[:15]:
    print(f"{size/1e9:8.2f}GB {op:18s} {shp:32s} {meta}")
