from repro.checkpoint.npz import load_pytree, save_pytree  # noqa: F401
