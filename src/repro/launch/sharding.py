"""GSPMD sharding rules for every architecture.

Baseline layout ("fsdp" mode, MaxText-style):
  * batch dims            -> ("pod","data") / ("data",)
  * attention/MLP weights -> tensor-parallel on the feature axis over "model",
                             parameter-sharded ("FSDP") on the other axis over
                             "data" when divisible;
  * MoE expert stacks     -> expert-parallel over "model" (leading E axis),
                             FSDP over "data" on d;
  * KV caches             -> batch over "data", head_dim over "model";
  * SSM states            -> batch over "data", ssm heads over "model";
  * scheduler state (VAoI ages, batteries, feature moments, per-client
    message stacks, and per-client harvest/stream state — Markov phases,
    drift mixtures, arrival counters) -> CLIENT-SHARDED over the data
    axes: the leading N axis is a fleet axis (``scheduler_pspec``;
    ``core/fleet.py`` runs the whole EHFL loop in this layout —
    DESIGN.md §9/§10; keys and clocks stay replicated).

"tp" mode drops the FSDP factor (params replicated over "data") — the
paper-era layout we baseline against in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, mesh, axis: str) -> Optional[str]:
    """Shard dim of size n over axis only if it divides evenly."""
    return axis if n % _axis_size(mesh, axis) == 0 else None


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh, batch: int, extra_dims: int = 1) -> P:
    dp = data_axes(mesh)
    total = 1
    for a in dp:
        total *= _axis_size(mesh, a)
    first = dp if batch % total == 0 else None
    return P(first, *([None] * extra_dims))


def param_pspec(path: str, leaf, mesh, mode: str = "fsdp", embed_mode: str | None = None) -> P:
    """Sharding rule by parameter name + rank. ``path`` is the '/'-joined
    key path; leaves may carry a leading stacked-blocks axis (rank+1).

    ``embed_mode`` overrides the embedding/LM-head rule:
      None / "fsdp" : (V->model, d->data)  — d-dim FSDP (baseline)
      "vocab_only"  : (V->model, None)     — no contraction-dim sharding, so
                      the LM-head matmul partitions without a giant
                      all-reduce of (B,S,V) partials (§Perf iteration 2).
    """
    shape = leaf.shape
    fsdp = mode == "fsdp"

    def d(n):  # data/fsdp factor
        return _div(n, mesh, "data") if fsdp else None

    def m(n):
        return _div(n, mesh, "model")

    name = path.split("/")[-1]
    rank = len(shape)

    # --- embeddings / heads: shard vocab over model, d over data ---
    if name in ("embed", "lm_head"):
        if embed_mode == "vocab_only":
            return P(m(shape[0]), None)
        return P(m(shape[0]), d(shape[1]))
    if name in ("pos_embed", "enc_pos_embed"):
        return P(None, m(shape[1]))
    # --- norms / scalars ---
    if "norm" in name or name in ("scale", "bias", "A_log", "dt_bias", "D", "conv_b", "bo"):
        return P(*([None] * rank))
    # --- MoE expert stacks: .../moe/w_* (not the shared expert, a plain MLP) ---
    if "/moe/" in f"/{path}/" and "/shared/" not in f"/{path}/":
        if name == "router":
            return P(*([None] * rank))
        if name in ("w_gate", "w_up", "w_down") and rank >= 3:
            # (..., E, a, b): expert-parallel over model, FSDP on a
            lead = [None] * (rank - 3)
            return P(*lead, m(shape[-3]), d(shape[-2]), None)
    # --- column-parallel (d -> features) ---
    if name in ("wq", "wk", "wv", "w_up", "w_gate", "in_proj", "shared_w_up"):
        lead = [None] * (rank - 2)
        return P(*lead, d(shape[-2]), m(shape[-1]))
    if name in ("bq", "bk", "bv"):
        lead = [None] * (rank - 1)
        return P(*lead, m(shape[-1]))
    # --- row-parallel (features -> d) ---
    if name in ("wo", "w_down", "out_proj"):
        lead = [None] * (rank - 2)
        return P(*lead, m(shape[-2]), d(shape[-1]))
    if name == "conv_w":  # (width, channels)
        lead = [None] * (rank - 2)
        return P(*lead, None, m(shape[-1]))
    return P(*([None] * rank))


def params_shardings(params_shape: Any, mesh, mode: str = "fsdp", embed_mode: str | None = None):
    """NamedSharding tree matching a params (shape) pytree."""

    def one(kp, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        return NamedSharding(mesh, param_pspec(path, leaf, mesh, mode, embed_mode))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def input_shardings(specs: dict, mesh) -> dict:
    out = {}
    for k, v in specs.items():
        b = v.shape[0]
        out[k] = NamedSharding(mesh, batch_spec(mesh, b, extra_dims=len(v.shape) - 1))
    return out


def cache_pspec(path: str, leaf, mesh, cfg: ModelConfig, batch_only: bool = False) -> P:
    """Decode caches. Leaves are stacked (n_blocks, B, ...)."""
    name = path.split("/")[-1]
    shape = leaf.shape
    dp = data_axes(mesh)
    total = 1
    for a in dp:
        total *= _axis_size(mesh, a)
    bdim = dp if shape[1] % total == 0 else None
    if name in ("k", "v", "ck", "cv"):  # (n_blocks, B, W|S_enc, nkv, hd)
        if batch_only:  # §Perf it.8: avoid GQA reshard, pay replicated cache
            return P(None, bdim, None, None, None)
        kv = _div(shape[3], mesh, "model")
        hd = _div(shape[4], mesh, "model")
        if kv and _axis_size(mesh, "model") <= shape[3]:
            return P(None, bdim, None, kv, None)
        return P(None, bdim, None, None, hd)
    if name == "conv":  # (n_blocks, B, w-1, ch)
        return P(None, bdim, None, _div(shape[3], mesh, "model"))
    if name == "ssm":  # (n_blocks, B, nh, hp, ds)
        return P(None, bdim, _div(shape[2], mesh, "model"), None, None)
    return P(*([None] * len(shape)))


def cache_shardings(cache_shape: Any, mesh, cfg: ModelConfig, batch_only: bool = False):
    def one(kp, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        return NamedSharding(mesh, cache_pspec(path, leaf, mesh, cfg, batch_only))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh):
    return NamedSharding(mesh, P())


def scheduler_pspec(mesh) -> P:
    """Per-client scheduler/fleet state (VAoI ages, batteries, feature
    moments, stacked message params, client datasets, and the per-client
    harvest/stream state leaves): the leading N axis shards over the data
    axes.  The global model and PRNG keys stay replicated — see
    ``core/fleet.py`` and DESIGN.md §9/§10."""
    return P(data_axes(mesh))
