"""End-to-end driver (deliverable b): the paper's §V experiment.

Trains the 6-conv CNN federatedly for a few hundred global rounds under
energy harvesting with VAoI scheduling, on the synthetic CIFAR-10-like
dataset (Dirichlet non-IID).  Defaults are CPU-feasible; pass --paper-scale
for the full N=100 / T=500 protocol on real hardware.

  PYTHONPATH=src python examples/ehfl_cifar.py --policy vaoi --rounds 200
"""
import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs.cifar_cnn import CONFIG as PAPER_CNN
from repro.configs.cifar_cnn import CNNConfig
from repro.core import (
    CHANNEL_SCENARIOS,
    SCENARIOS,
    STREAM_SCENARIOS,
    EHFLConfig,
    run_batch,
    run_simulation,
)
from repro.data import make_federated_dataset
from repro.fl import cnn_backend


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="vaoi",
                    choices=["vaoi", "fedavg", "fedbacys", "fedbacys_odd"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--samples", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--p-bc", type=float, default=0.1)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--mu", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--harvest", default="bernoulli", choices=list(SCENARIOS),
                    help="energy-arrival scenario (repro.core.harvest)")
    ap.add_argument("--stream", default="static", choices=list(STREAM_SCENARIOS),
                    help="streaming-data scenario (repro.data.stream): static "
                         "is the paper's frozen partition; drift/arrival/shift "
                         "make client data non-stationary over epochs")
    ap.add_argument("--stream-period", type=float, default=0.0,
                    help="override the drift/shift period (epochs; 0 = scenario default)")
    ap.add_argument("--channel", default="ideal", choices=list(CHANNEL_SCENARIOS),
                    help="uplink channel scenario (repro.core.channel): ideal "
                         "is the paper's lossless uplink; erasure/aloha/fading "
                         "drop uploads, which retry with capped exponential "
                         "backoff and re-age their VAoI (DESIGN.md §12)")
    ap.add_argument("--channel-params", default="",
                    help="comma list of k=v channel knobs, e.g. "
                         "'p_loss=0.3,concentration=1.0' (erasure), "
                         "'num_channels=4' (aloha), 'p_bad=0.4,sojourn=2' (fading)")
    ap.add_argument("--num-seeds", type=int, default=1,
                    help=">1: vmapped multi-seed sweep in one jitted call (run_batch)")
    ap.add_argument("--fleet", action="store_true",
                    help="client-sharded fleet simulator (core/fleet.py) over all "
                         "visible devices; virtualize CPU devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    ap.add_argument("--paper-scale", action="store_true",
                    help="full paper protocol: N=100, T=500, 300 samples, 32px CNN")
    ap.add_argument("--out", default="experiments/ehfl_cifar")
    args = ap.parse_args()
    if args.fleet and args.num_seeds > 1:
        ap.error("--fleet runs a single seed; drop --num-seeds "
                 "(seed sweeps go through run_batch, fleets through run_fleet)")

    if args.paper_scale:
        args.clients, args.rounds, args.samples, args.k = 100, 500, 300, 10
        cnn, image = PAPER_CNN, 32
    else:
        cnn = CNNConfig(name="driver", image_size=16,
                        conv_channels=(16, 16, 32, 32, 64, 64), fc_dims=(128, 64))
        image = 16

    print(f"EHFL driver: policy={args.policy} N={args.clients} T={args.rounds} "
          f"alpha={args.alpha} p_bc={args.p_bc} harvest={args.harvest} "
          f"stream={args.stream} cnn={cnn.conv_channels}")
    data = make_federated_dataset(
        jax.random.PRNGKey(args.seed), num_clients=args.clients,
        samples_per_client=args.samples, alpha=args.alpha, test_size=500,
        image_size=image,
    )
    cfg = EHFLConfig(
        num_clients=args.clients, epochs=args.rounds, slots_per_epoch=30,
        kappa=20, p_bc=args.p_bc, k=args.k, mu=args.mu, e_max=25,
        policy=args.policy, alpha=args.alpha, seed=args.seed,
        eval_every=max(args.rounds // 10, 1), probe_size=20, lr=0.01,
        harvest=args.harvest, stream=args.stream,
        stream_params=(("period", args.stream_period),)
        if args.stream_period > 0 and args.stream in ("drift", "shift") else (),
        channel=args.channel,
        channel_params=tuple(
            (k, float(v))
            for k, v in (kv.split("=", 1) for kv in args.channel_params.split(",") if kv)
        ),
    )
    backend = cnn_backend(cnn)
    t0 = time.time()
    if args.fleet:
        from repro.core.fleet import run_fleet

        out = run_fleet(cfg, backend, data)
        wall = time.time() - t0
        m = out["metrics"]
        params = out["global_params"]
        print(f"fleet: N={args.clients} sharded over {out['num_shards']} device(s)")
    elif args.num_seeds > 1:
        seeds = [args.seed + i for i in range(args.num_seeds)]
        out = run_batch(cfg, backend, data, seeds)
        wall = time.time() - t0
        # report seed means (every metric has a leading seed axis except the
        # shared eval schedule); keep seed 0's model for the checkpoint
        m = {k: np.asarray(v) if k == "f1_epochs" else np.asarray(v).mean(0)
             for k, v in out["metrics"].items()}
        params = jax.tree.map(lambda x: x[0], out["global_params"])
    else:
        out = run_simulation(cfg, backend, data)
        wall = time.time() - t0
        m = out["metrics"]
        params = out["global_params"]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    tag = f"{args.policy}_{args.harvest}_{args.stream}_a{args.alpha}_p{args.p_bc}"
    save_pytree(params, outdir / f"{tag}_model.npz")
    (outdir / f"{tag}_metrics.json").write_text(json.dumps({
        "f1": np.asarray(m["f1"]).tolist(),
        "f1_epochs": np.asarray(m["f1_epochs"]).tolist(),
        "avg_age": np.asarray(m["avg_age"]).tolist(),
        "energy": np.asarray(m["energy"]).tolist(),
        "total_energy": float(m["total_energy"]),
        "num_seeds": args.num_seeds,
        "wall_s": wall,
    }))
    print(f"f1 trajectory: {[round(float(x), 4) for x in np.asarray(m['f1'])]}")
    print(f"total energy: {float(m['total_energy']):.0f} units | "
          f"trainings: {int(np.asarray(m['n_started']).sum())} | wall: {wall:.1f}s")
    print(f"saved model+metrics -> {outdir}/{tag}_*")


if __name__ == "__main__":
    main()
