"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no bias, layernorm."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        norm="layernorm",
        tie_embeddings=True,
        rope_theta=8_000_000.0,
        dtype=jnp.bfloat16,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )
)
