"""Config system: model architecture + input-shape + EHFL scheduling configs.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` that
exports ``CONFIG`` (the exact published spec) built from :class:`ModelConfig`.
``reduced()`` derives the CPU smoke-test variant (<=2 layers, d_model<=512,
<=4 experts).  ``input_specs()`` builds ShapeDtypeStruct stand-ins for the
dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (gated) | gelu (non-gated, whisper/cnn style)
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0  # routed experts; 0 => dense FFN
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1  # layer i uses MoE iff num_experts>0 and i % moe_period == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # --- hybrid (jamba): attention layer iff i % attn_period == attn_offset ---
    attn_period: int = 1  # 1 => every layer is attention
    attn_offset: int = 0
    # --- SSM (mamba2) ---
    ssm_state: int = 0  # 0 => no ssm layers
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 1500 mel frames after conv frontend (stubbed)
    # --- modality frontend stubs ---
    num_prefix_tokens: int = 0  # VLM: patch embeddings prepended, provided by input_specs
    # --- attention windowing (0 = full attention) ---
    sliding_window: int = 0
    # window used only for the long_500k decode variant of dense archs:
    long_context_window: int = 8192
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    source: str = ""  # citation

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived -----------------------------------------------------------
    @property
    def is_ssm_only(self) -> bool:
        return self.ssm_state > 0 and self.attn_period == 0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' token mixer for layer i."""
        if self.ssm_state == 0:
            return "attn"
        if self.attn_period == 0:  # pure SSM
            return "ssm"
        return "attn" if i % self.attn_period == self.attn_offset else "ssm"

    def layer_moe(self, i: int) -> bool:
        return self.num_experts > 0 and i % self.moe_period == self.moe_offset

    @property
    def block_period(self) -> int:
        """Layers are scanned in super-blocks of this period (homogeneous stacking)."""
        import math

        p = 1
        if self.ssm_state > 0 and self.attn_period > 1:
            p = self.attn_period
        if self.num_experts > 0 and self.moe_period > 1:
            p = p * self.moe_period // math.gcd(p, self.moe_period)
        return p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        hd, nh, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += d * nh * hd + 2 * d * nkv * hd + nh * hd * d
                if self.qkv_bias:
                    total += (nh + 2 * nkv) * hd
            else:  # ssm
                di, ds, nhs = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * ds + nhs)  # in_proj (z,x,B,C,dt)
                total += (di + 2 * ds) * self.ssm_conv_width
                total += nhs * 2 + di  # A_log, dt_bias, D
                total += di * d  # out_proj
            if self.layer_moe(i):
                ne = self.num_experts + self.num_shared_experts
                total += ne * 3 * d * ff + d * self.num_experts  # experts + router
            elif kind == "attn" or self.ssm_state == 0 or self.d_ff > 0:
                if self.d_ff > 0 and (kind == "attn" or self.family != "ssm"):
                    total += 3 * d * ff
            total += 2 * d  # norms
        if self.is_encoder_decoder:
            for _ in range(self.num_encoder_layers):
                total += d * nh * hd + 2 * d * nkv * hd + nh * hd * d  # self
                total += 2 * (d * ff) + d * ff  # mlp (gelu: 2 mats ~ keep 3 for simplicity)
                # cross attention in decoder counted below
            total += self.num_layers * (d * nh * hd + 2 * d * nkv * hd + nh * hd * d + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.num_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        total = self.param_count()
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.layer_moe(i))
        inactive = (self.num_experts - self.experts_per_token) * 3 * d * ff * n_moe_layers
        return total - inactive


# ---------------------------------------------------------------------------
# Reduced (smoke) variant
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant of the same family: <=2 layers (respecting the
    block period), d_model<=512, <=4 experts, small vocab."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    head_dim = d_model // n_heads if n_heads else 0
    n_kv = max(1, min(cfg.num_kv_heads, n_heads)) if n_heads else 0
    # keep the GQA ratio flavour
    if n_heads and cfg.num_kv_heads < cfg.num_heads:
        n_kv = max(1, n_heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    changes: Dict[str, Any] = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        dtype=jnp.float32,
        ssm_chunk=64,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        long_context_window=128,
    )
    if cfg.num_experts > 0:
        changes.update(
            num_experts=4,
            experts_per_token=min(cfg.experts_per_token, 2),
            num_shared_experts=min(cfg.num_shared_experts, 1),
        )
    if cfg.ssm_state > 0:
        changes.update(ssm_state=16, ssm_head_dim=32)
        if cfg.attn_period > 1:  # hybrid: keep the interleave at 2 layers (ssm, attn)
            changes.update(attn_period=2, attn_offset=1, moe_period=min(cfg.moe_period, 2))
    if cfg.is_encoder_decoder:
        changes.update(num_encoder_layers=2, encoder_seq=16)
    if cfg.num_prefix_tokens > 0:
        changes.update(num_prefix_tokens=8)
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for the given (arch, input-shape) pair.

    train/prefill: token ids (+labels for train) (B, S); modality stubs add
    precomputed embeddings (the carve-out: frontend outputs, not raw media).
    decode: one new token per sequence + cache handled by the caller.
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = sds((B, S), jnp.int32)
        specs["labels"] = sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = sds((B, S), jnp.int32)
    else:  # decode: one token, cache of length S built by init_cache
        specs["tokens"] = sds((B, 1), jnp.int32)
        specs["positions"] = sds((B,), jnp.int32)
    if cfg.num_prefix_tokens > 0 and shape.kind != "decode":
        specs["prefix_embeddings"] = sds((B, cfg.num_prefix_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        # stubbed audio frontend: mel+conv output frames
        specs["encoder_frames"] = sds((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return specs


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_ARCH_MODULES = [
    "deepseek_moe_16b",
    "internvl2_2b",
    "llama4_scout_17b_a16e",
    "jamba_v0_1_52b",
    "command_r_35b",
    "starcoder2_3b",
    "qwen1_5_0_5b",
    "codeqwen1_5_7b",
    "whisper_large_v3",
    "mamba2_1_3b",
    "cifar_cnn",
]

_loaded = False


def _ensure_loaded() -> None:
    global _loaded
    if _loaded:
        return
    import importlib

    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
