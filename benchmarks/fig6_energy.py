"""Paper Fig. 6: network-wide energy consumption, normalized within each
p_bc group by the max across the 4 schemes.

Claims validated: (i) energy is driven by participation frequency, not by
alpha; (ii) VAoI consumes substantially less than greedy FedAvg at high
p_bc (paper: up to 37% reduction); (iii) FedBacys-Odd is lowest."""
from __future__ import annotations

import numpy as np

from benchmarks.ehfl_grid import POLICIES, run_grid


def run(quick: bool = True):
    cells, st = run_grid(quick)
    rows = []
    alphas = sorted({a for (_, a, _) in cells})
    pbcs = sorted({p for (_, _, p) in cells})
    a_ref = alphas[0]  # paper uses alpha=0.1 for fig 6
    for p_bc in pbcs:
        totals = {pol: cells[(pol, a_ref, p_bc)]["total_energy"] for pol in POLICIES}
        mx = max(totals.values()) or 1.0
        for pol, e in totals.items():
            rows.append(
                {
                    "name": f"fig6/{pol}/p{p_bc}",
                    "us_per_call": 0.0,
                    "derived": f"energy={e:.0f};normalized={e/mx:.3f}",
                }
            )
        if totals["fedavg"] > 0:
            red = 1.0 - totals["vaoi"] / totals["fedavg"]
            rows.append(
                {
                    "name": f"fig6/vaoi_vs_fedavg_reduction/p{p_bc}",
                    "us_per_call": 0.0,
                    "derived": f"reduction={red:.3f}",
                }
            )
    # alpha-invariance of energy (claim i): compare vaoi energy across alphas
    if len(alphas) > 1:
        for p_bc in pbcs:
            es = [cells[("vaoi", a, p_bc)]["total_energy"] for a in alphas]
            spread = (max(es) - min(es)) / (max(es) or 1.0)
            rows.append(
                {
                    "name": f"fig6/alpha_invariance/p{p_bc}",
                    "us_per_call": 0.0,
                    "derived": f"rel_spread={spread:.3f}",
                }
            )
    return rows
