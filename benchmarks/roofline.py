"""Deliverable (g): roofline table from the dry-run artifacts.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS ratio."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_records():
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        try:
            r = json.loads(f.read_text())
            r["_file"] = f.stem
            recs.append(r)
        except Exception:
            pass
    return recs


def run(quick: bool = True):
    rows = []
    for rec in load_records():
        variant = rec["_file"].split("__", 2)[-1].replace("__", "+")
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}/{variant}"
        if "skipped" in rec:
            rows.append({"name": name, "us_per_call": 0.0, "derived": f"skipped={rec['skipped']}"})
            continue
        if "error" in rec:
            rows.append({"name": name, "us_per_call": 0.0, "derived": "ERROR"})
            continue
        r = rec["roofline"]
        dom = r["bottleneck"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ratio = rec.get("useful_flop_ratio")
        rows.append(
            {
                "name": name,
                "us_per_call": step_s * 1e6,  # roofline-bound step time
                "derived": (
                    f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
                    f"collective_s={r['collective_s']:.3e};bottleneck={dom};"
                    f"useful_flop_ratio={ratio:.3f}" if ratio else f"bottleneck={dom}"
                ),
            }
        )
    if not rows:
        rows.append({"name": "roofline/NO_DRYRUN_DATA", "us_per_call": 0.0,
                     "derived": "run: python -m repro.launch.dryrun --all"})
    return rows
