"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE 16e top-1, early fusion."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        num_experts=16,
        num_shared_experts=1,
        experts_per_token=1,
        moe_period=1,
        rope_theta=500_000.0,
        dtype=jnp.bfloat16,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
)
