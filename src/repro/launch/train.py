"""At-scale EHFL training driver.

Runs VAoI-scheduled federated rounds where each client's local model is one
of the assigned architectures (``--arch``), distributed over a jax mesh.
On this CPU container it runs reduced configs on a host mesh; on real
hardware the same code paths target the production mesh in ``mesh.py``.

Example (CPU, reduced):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --clients 8 --rounds 3 --k 2 --steps-per-round 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.configs import get_config, reduced
from repro.core import vaoi as vaoi_lib
from repro.data import make_token_dataset
from repro.models import decoder
from repro.optim import sgd_update


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--steps-per-round", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mu", type=float, default=0.001)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(args.seed)
    kd, kp, kr = jax.random.split(key, 3)

    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} vocab={cfg.vocab_size}")
    data = make_token_dataset(
        kd, args.clients, args.batch * args.steps_per_round, args.seq, cfg.vocab_size
    )["tokens"]  # (N, n, S)
    params = decoder.init_params(cfg, kp, max_seq=args.seq)


    @jax.jit
    def local_round(params, toks):  # toks: (steps, batch, S)
        def step(p, tb):
            batch = {"tokens": tb, "labels": tb}
            (l, _), g = jax.value_and_grad(lambda p_: decoder.loss_fn(cfg, p_, batch), has_aux=True)(p)
            return sgd_update(p, g, args.lr), l

        params, losses = jax.lax.scan(step, params, toks)
        return params, losses.mean()

    @jax.jit
    def probe_feature(params, toks):
        return decoder.feature_vector(cfg, params, toks)

    N = args.clients
    age = jnp.zeros((N,), jnp.float32)
    h = jnp.zeros((N, cfg.vocab_size), jnp.float32)
    for r in range(args.rounds):
        kr, ks = jax.random.split(kr)
        # Alg. 2: one forward pass per client on the global model
        v = jnp.stack([probe_feature(params, data[i, : args.batch]) for i in range(N)])
        selected, age, m = vaoi_lib.client_select(age, v, h, args.k, args.mu, ks)
        idx = [int(i) for i in jnp.nonzero(selected)[0]]
        new_params, losses = [], []
        for i in idx:
            toks = data[i].reshape(args.steps_per_round, args.batch, args.seq)
            p_i, l_i = local_round(params, toks)
            new_params.append(p_i)
            h = h.at[i].set(probe_feature(p_i, data[i, : args.batch]))
            losses.append(float(l_i))
        params = jax.tree.map(lambda *xs: sum(xs) / len(xs), *new_params)
        print(
            f"round {r}: selected={idx} loss={sum(losses)/len(losses):.4f} "
            f"avg_age={float(age.mean()):.2f} avg_M={float(m.mean()):.4f}"
        )
    if args.save:
        save_pytree(params, args.save)
        print(f"saved -> {args.save}")


if __name__ == "__main__":
    main()
