"""Data pipeline: Dirichlet partition skew + synthetic set learnability."""
import jax
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import dirichlet_label_partition, make_federated_dataset, make_token_dataset


@given(alpha=st.sampled_from([0.1, 1.0, 10.0]), seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_partition_shapes_and_range(alpha, seed):
    labels = dirichlet_label_partition(jax.random.PRNGKey(seed), 20, 50, 10, alpha)
    assert labels.shape == (20, 50)
    assert int(labels.min()) >= 0 and int(labels.max()) < 10


def test_smaller_alpha_is_more_skewed():
    """Mean per-client label entropy decreases with alpha (non-IID severity)."""
    def mean_entropy(alpha):
        labels = np.asarray(
            dirichlet_label_partition(jax.random.PRNGKey(0), 100, 300, 10, alpha)
        )
        ents = []
        for row in labels:
            p = np.bincount(row, minlength=10) / row.size
            p = p[p > 0]
            ents.append(-(p * np.log(p)).sum())
        return float(np.mean(ents))

    e01, e1, e10 = mean_entropy(0.1), mean_entropy(1.0), mean_entropy(10.0)
    assert e01 < e1 < e10


def test_federated_dataset_shapes():
    d = make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=5, samples_per_client=40, test_size=100
    )
    assert d["images"].shape == (5, 40, 32, 32, 3)
    assert d["labels"].shape == (5, 40)
    assert d["test_images"].shape == (100, 32, 32, 3)
    # balanced test labels
    counts = np.bincount(np.asarray(d["test_labels"]), minlength=10)
    assert counts.min() == counts.max() == 10


def test_synthetic_classes_are_separable():
    """A nearest-prototype classifier beats chance by a wide margin."""
    d = make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=2, samples_per_client=10, test_size=500, noise=0.8
    )
    X = np.asarray(d["test_images"]).reshape(500, -1)
    y = np.asarray(d["test_labels"])
    protos = np.stack([X[y == c].mean(0) for c in range(10)])
    preds = np.argmin(((X[:, None] - protos[None]) ** 2).sum(-1), axis=1)
    assert (preds == y).mean() > 0.8


def test_token_dataset_topic_skew():
    d = make_token_dataset(jax.random.PRNGKey(0), 4, 8, 32, vocab_size=512, alpha=0.1)
    toks = np.asarray(d["tokens"])
    assert toks.shape == (4, 8, 32)
    # different clients use visibly different vocab distributions
    h0 = np.bincount(toks[0].ravel(), minlength=512)
    h1 = np.bincount(toks[1].ravel(), minlength=512)
    overlap = np.minimum(h0, h1).sum() / max(h0.sum(), 1)
    assert overlap < 0.8
