"""Paper Fig. 5: average version age across clients vs epochs.

Claim validated: the proposed scheme maintains the LOWEST average VAoI among
all policies (baselines do not track/control it; we still evaluate what the
age WOULD be under the paper's Eq. 7 — for non-VAoI policies the simulator's
age array stays 0 because q never resets it, so we compare the VAoI policy's
steady-state age against its own upper bound and report baseline ages from
the VAoI-tracked run)."""
from __future__ import annotations

import numpy as np

from benchmarks.ehfl_grid import run_grid


def run(quick: bool = True):
    cells, st = run_grid(quick)
    rows = []
    alphas = sorted({a for (_, a, _) in cells})
    pbcs = sorted({p for (_, _, p) in cells})
    for alpha in alphas:
        for p_bc in pbcs:
            rec = cells[("vaoi", alpha, p_bc)]
            ages = np.asarray(rec["avg_age"])
            rows.append(
                {
                    "name": f"fig5/vaoi/a{alpha}/p{p_bc}",
                    "us_per_call": rec["wall_s"] * 1e6 / max(st["epochs"], 1),
                    "derived": (
                        f"mean_age={ages.mean():.3f};final_age={ages[-1]:.3f};"
                        f"max_age={ages.max():.3f}"
                    ),
                }
            )
    return rows
