"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

TPU-native formulation: the sequence is processed in chunks — intra-chunk
work is dense matmuls (MXU-friendly), inter-chunk state carry is a
``lax.associative_scan`` over chunk summaries.  Decode is the O(1) recurrent
state update.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init


def init_ssd(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * ds
    ks = jax.random.split(key, 4)
    # in_proj emits [z (di), x (di), B (ds), C (ds), dt (nh)]
    p: Params = {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ds + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }
    return p


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ds]
    dt = zxbcdt[..., 2 * di + 2 * ds :]
    return z, xBC, dt


def _gated_norm(p: Params, y: jax.Array, z: jax.Array, eps: float) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * p["norm_scale"].astype(jnp.float32)).astype(y.dtype)


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L). Returns S with S[..., i, j] = sum_{j<m<=i} a[..., m] (lower-tri), -inf above diag."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j) = sum_{j<m<=i}
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, nh, hp)
    dt: jax.Array,  # (B, S, nh) post-softplus
    A: jax.Array,  # (nh,) negative
    Bm: jax.Array,  # (B, S, ds)
    Cm: jax.Array,  # (B, S, ds)
    chunk: int,
    init_state: jax.Array | None = None,  # (B, nh, hp, ds)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,nh,hp), final_state (B,nh,hp,ds))."""
    B_, S, nh, hp = x.shape
    ds = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc, L = Sp // chunk, chunk

    xd = (x * dt[..., None]).astype(jnp.float32)  # (B,Sp,nh,hp)
    a = (dt * A[None, None, :]).astype(jnp.float32)  # (B,Sp,nh) negative increments

    # chunked views
    xc = xd.reshape(B_, nc, L, nh, hp)
    ac = a.reshape(B_, nc, L, nh).transpose(0, 3, 1, 2)  # (B,nh,nc,L)
    Bc = Bm.reshape(B_, nc, L, ds).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, L, ds).astype(jnp.float32)

    a_cum = jnp.cumsum(ac, axis=-1)  # (B,nh,nc,L)

    # 1) intra-chunk (diagonal blocks): quadratic within chunk — dense matmuls
    Lmat = jnp.exp(_segsum(ac))  # (B,nh,nc,L,L)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, Lmat, xc)

    # 2) chunk summaries: end-state contribution of each chunk
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,nh,nc,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)  # (B,nc,nh,hp,ds)

    # 3) inter-chunk recurrence: S_c = S_{c-1} * exp(sum a_c) + states_c
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B,nh,nc)

    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec = chunk_decay.transpose(0, 2, 1)  # (B,nc,nh)
    if init_state is None:
        init_state = jnp.zeros((B_, nh, hp, ds), jnp.float32)
    else:
        init_state = init_state.astype(jnp.float32)
    run_dec, run_state = jax.lax.associative_scan(combine, (dec, states), axis=1)
    # state entering chunk c = run_state[c-1] + (prod of decays before c) * S0
    cum_dec_in = jnp.concatenate([jnp.ones_like(dec[:, :1]), run_dec[:, :-1]], axis=1)
    prev_states = (
        jnp.concatenate([jnp.zeros_like(run_state[:, :1]), run_state[:, :-1]], axis=1)
        + cum_dec_in[..., None, None] * init_state[:, None]
    )
    final_state = run_state[:, -1] + run_dec[:, -1][..., None, None] * init_state

    state_decay_out = jnp.exp(a_cum)  # (B,nh,nc,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(B_, Sp, nh, hp)[:, :S]
    return y, final_state


def ssd_forward(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    state: Dict[str, jax.Array] | None = None,
    use_kernel: bool = False,
) -> jax.Array:
    """Full-sequence mamba2 block. x: (B, S, d) -> (B, S, d).

    ``use_kernel=True`` routes the scan through the fused Pallas
    ``ssd_scan`` kernel (TPU; interpret mode on CPU) instead of the
    pure-jnp chunked form — identical math, VMEM-resident intermediates."""
    B, S, d = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # causal depthwise conv, width w
    w = cfg.ssm_conv_width
    xBC_pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
    conv = sum(xBC_pad[:, i : i + S, :] * p["conv_w"][i][None, None, :] for i in range(w))
    xBC = jax.nn.silu(conv + p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, nh, hp)
    Bm, Cm = xBC[..., di : di + ds], xBC[..., di + ds :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    if use_kernel:
        from repro.kernels import ops as kops

        y, _ = kops.ssd_scan(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode (recurrent O(1) step)
# ---------------------------------------------------------------------------


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di, ds = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * ds), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, ds), jnp.float32),
    }


def ssd_decode(
    cfg: ModelConfig, p: Params, x: jax.Array, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, d) -> (y (B,1,d), new cache)."""
    B = x.shape[0]
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ p["in_proj"]  # (B, ...)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    hist = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B, w, ch)
    conv = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    xBC_t = jax.nn.silu(conv)
    xt = xBC_t[:, :di].reshape(B, nh, hp)
    Bt, Ct = xBC_t[:, di : di + ds], xBC_t[:, di + ds :]
    dt_t = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_t * A[None, :])  # (B,nh)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, xt.astype(jnp.float32), Bt.astype(jnp.float32))
    h_new = cache["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, Ct.astype(jnp.float32))
    y = y + xt.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, di).astype(x.dtype)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": hist[:, 1:], "ssm": h_new}
