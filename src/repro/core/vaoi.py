"""Version Age of Information (VAoI) — Eq. (2)/(7) of the paper, plus the
feature-based dissimilarity proxy M_i (Eq. 5) and Alg. 2 client selection.

All functions are pure jnp (the Pallas kernel in ``repro.kernels`` is the
TPU-optimized fused version of :func:`vaoi_update`; ``tests/test_kernels.py``
asserts they agree).

The ``*_sharded`` variants are the distributed forms used when the client
axis is sharded over a mesh axis (DESIGN.md §9): each shard takes a local
top-k of candidates, the ``2·shards·k`` (score, index) pairs are
all-gathered, and a global top-k over the candidate set reproduces the
single-device selection bit-for-bit (the true global top-k is always
contained in the union of per-shard top-k sets).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def feature_distance(v: jax.Array, h: jax.Array) -> jax.Array:
    """M_i = ||v_i - h_i||_2 per client. v, h: (N, F) -> (N,)."""
    diff = v.astype(jnp.float32) - h.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


def vaoi_update(age: jax.Array, m: jax.Array, q: jax.Array, mu: float) -> jax.Array:
    """Eq. (7): X(t+1) = (X+1)(1-q) if M >= mu else X(1-q).

    age: (N,) float; m: (N,) distances; q: (N,) {0,1} participation.
    """
    inc = jnp.where(m >= mu, age + 1.0, age)
    return inc * (1.0 - q.astype(age.dtype))


def select_topk(age: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """Alg. 2: normalize p_i = X_i / sum X_j, take the k largest.

    Random tie-breaking (also covers the all-zero cold start, where selection
    degenerates to uniform sampling of k clients). Returns a boolean mask (N,).
    """
    n = age.shape[0]
    noise = jax.random.uniform(key, (n,), minval=0.0, maxval=1e-3)
    total = jnp.sum(age)
    p = jnp.where(total > 0, age / jnp.maximum(total, 1e-12), 0.0)
    scores = p + noise
    _, idx = jax.lax.top_k(scores, k)
    return jnp.zeros((n,), bool).at[idx].set(True)


def select_gumbel(age: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """Sample k clients WITHOUT replacement with probability proportional to
    p_i = X_i / sum X_j (Gumbel-top-k).  A stochastic variant of Alg. 2's
    deterministic top-k (beyond-paper ablation: exploration under ties)."""
    n = age.shape[0]
    logp = jnp.where(age > 0, jnp.log(jnp.maximum(age, 1e-12)), -20.0)
    g = jax.random.gumbel(key, (n,))
    _, idx = jax.lax.top_k(logp + g, k)
    return jnp.zeros((n,), bool).at[idx].set(True)


def _distributed_topk(scores: jax.Array, k: int, axis_name: str) -> jax.Array:
    """Global top-k over a client-sharded score vector -> local (N_loc,) mask.

    Local top-k of kk = min(k, N_loc) candidates per shard, all-gather the
    (score, global index) pairs, then a global top-k over the candidate set.
    Every element of the true global top-k has local rank <= k on its own
    shard (the orderings agree), so the candidate union is a superset.
    Ordering by (score desc, index asc) reproduces ``lax.top_k``'s
    lower-index tie-break exactly — the selection is bit-identical to
    ``lax.top_k`` on the all-gathered vector.
    """
    n_loc = scores.shape[0]
    shard = jax.lax.axis_index(axis_name)
    kk = min(k, n_loc)
    loc_scores, loc_idx = jax.lax.top_k(scores, kk)
    cand_scores = jax.lax.all_gather(loc_scores, axis_name, tiled=True)
    cand_idx = jax.lax.all_gather(loc_idx + shard * n_loc, axis_name, tiled=True)
    order = jnp.lexsort((cand_idx, -cand_scores))
    top_idx = cand_idx[order[: min(k, cand_idx.shape[0])]]
    # scatter the selected global indices that land on this shard
    pos = top_idx - shard * n_loc
    pos = jnp.where((pos >= 0) & (pos < n_loc), pos, n_loc)  # OOB -> dropped
    return jnp.zeros((n_loc,), bool).at[pos].set(True, mode="drop")


def select_topk_sharded(
    age: jax.Array, k: int, key: jax.Array, *, axis_name: str, n_global: int
) -> jax.Array:
    """Distributed Alg. 2 (:func:`select_topk` with ``age`` client-sharded).

    The tie-break noise is drawn with the *global* shape from the replicated
    key and sliced per shard, and the normalizer is a ``psum``, so scores —
    and hence the selection — match the single-device path bit-for-bit
    (ages are integer-valued floats: their sum is exact in any order).
    """
    n_loc = age.shape[0]
    off = jax.lax.axis_index(axis_name) * n_loc
    noise = jax.lax.dynamic_slice(
        jax.random.uniform(key, (n_global,), minval=0.0, maxval=1e-3), (off,), (n_loc,)
    )
    total = jax.lax.psum(jnp.sum(age), axis_name)
    p = jnp.where(total > 0, age / jnp.maximum(total, 1e-12), 0.0)
    return _distributed_topk(p + noise, k, axis_name)


def select_gumbel_sharded(
    age: jax.Array, k: int, key: jax.Array, *, axis_name: str, n_global: int
) -> jax.Array:
    """Distributed :func:`select_gumbel` (same global-draw-and-slice recipe)."""
    n_loc = age.shape[0]
    off = jax.lax.axis_index(axis_name) * n_loc
    logp = jnp.where(age > 0, jnp.log(jnp.maximum(age, 1e-12)), -20.0)
    g = jax.lax.dynamic_slice(
        jax.random.gumbel(key, (n_global,)), (off,), (n_loc,)
    )
    return _distributed_topk(logp + g, k, axis_name)


def client_select(
    age: jax.Array,
    v: jax.Array,
    h: jax.Array,
    k: int,
    mu: float,
    key: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Alg. 2 CLIENTSELECT: returns (selected mask, new ages, distances M).

    v: (N, F) feature vectors of the *global* model on each client's probe
    batch (one forward pass, line 7); h: (N, F) stored historical moments.
    """
    selected = select_topk(age, k, key)
    m = feature_distance(v, h)
    q = selected.astype(jnp.float32)
    new_age = vaoi_update(age, m, q, mu)
    return selected, new_age, m
