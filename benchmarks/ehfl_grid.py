"""Shared EHFL sweep powering the Fig. 4 / 5 / 6 benchmarks.

Paper protocol (§V) scaled to this CPU container: the full protocol is
N=100 clients, T=500 epochs, 300 samples; the sweep below keeps every
structural constant (S=30, kappa=20, E_max=kappa+5, k=10 scaled to N,
mu=0.5, Dirichlet alpha grid, p_bc grid) and shrinks N/T/samples.
Results are cached to experiments/ehfl_grid/<tag>.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_simulation
from repro.data import make_federated_dataset
from repro.fl import cnn_backend

CACHE = Path(__file__).resolve().parent.parent / "experiments" / "ehfl_grid"

BENCH_CNN = CNNConfig(name="bench", image_size=16, conv_channels=(8, 8, 16, 16, 32, 32), fc_dims=(64, 32))

POLICIES = ("vaoi", "fedavg", "fedbacys", "fedbacys_odd")


def grid_settings(quick: bool):
    if quick:
        return dict(
            alphas=(0.1, 1.0),
            pbcs=(0.1, 1.0),
            num_clients=16,
            samples=40,
            epochs=30,
            eval_every=6,
            k=4,
        )
    return dict(
        alphas=(0.1, 1.0, 10.0),
        pbcs=(0.01, 0.1, 1.0),
        num_clients=40,
        samples=120,
        epochs=120,
        eval_every=10,
        k=8,
    )


def run_cell(policy: str, alpha: float, p_bc: float, st: dict, seed: int = 0) -> dict:
    tag = (
        f"{policy}_a{alpha}_p{p_bc}_N{st['num_clients']}_T{st['epochs']}"
        f"_n{st['samples']}_s{seed}"
    )
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / f"{tag}.json"
    if f.exists():
        return json.loads(f.read_text())
    data = make_federated_dataset(
        jax.random.PRNGKey(seed),
        num_clients=st["num_clients"],
        samples_per_client=st["samples"],
        alpha=alpha,
        test_size=300,
        image_size=BENCH_CNN.image_size,
    )
    cfg = EHFLConfig(
        num_clients=st["num_clients"],
        epochs=st["epochs"],
        slots_per_epoch=30,
        kappa=20,
        p_bc=p_bc,
        k=st["k"],
        mu=0.5,
        e_max=25,
        policy=policy,
        alpha=alpha,
        seed=seed,
        eval_every=st["eval_every"],
        probe_size=20,
    )
    t0 = time.time()
    out = run_simulation(cfg, cnn_backend(BENCH_CNN), data)
    m = out["metrics"]
    rec = {
        "policy": policy,
        "alpha": alpha,
        "p_bc": p_bc,
        "wall_s": round(time.time() - t0, 1),
        "f1": np.asarray(m["f1"]).tolist(),
        "f1_epochs": np.asarray(m["f1_epochs"]).tolist(),
        "avg_age": np.asarray(m["avg_age"]).tolist(),
        "energy_per_epoch": np.asarray(m["energy"]).tolist(),
        "total_energy": float(m["total_energy"]),
        "n_started": int(np.asarray(m["n_started"]).sum()),
        "n_uploaded": int(np.asarray(m["n_uploaded"]).sum()),
    }
    f.write_text(json.dumps(rec))
    return rec


def run_grid(quick: bool = True, seed: int = 0):
    st = grid_settings(quick)
    cells = {}
    for alpha in st["alphas"]:
        for p_bc in st["pbcs"]:
            for policy in POLICIES:
                cells[(policy, alpha, p_bc)] = run_cell(policy, alpha, p_bc, st, seed)
    return cells, st
