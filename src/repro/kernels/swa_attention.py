"""Pallas TPU kernel: blockwise (flash) attention with causal and
sliding-window masking — the sub-quadratic attention used by the dense
assigned architectures for long-context shapes.

Online-softmax over KV blocks: grid (B*H, S/BQ, S/BK) with the KV axis
innermost; scratch keeps the running max m, normalizer l, and the (BQ, D)
fp32 accumulator in VMEM.  Block sizes default to 128 (MXU-aligned).
Window masking is applied per-block; blocks entirely outside
(i - window, i] are skipped via a cheap whole-block predicate so the kernel
does O(S * window) work, not O(S^2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(scale: float, window: int, causal: bool, bq: int, bk: int):
    def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        iq = pl.program_id(1)
        jk = pl.program_id(2)
        nk = pl.num_programs(2)

        @pl.when(jk == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q_start = iq * bq
        k_start = jk * bk
        # whole-block skip predicate: any (q, k) pair in range?
        live = jnp.asarray(True)
        if causal:
            live &= k_start <= q_start + bq - 1  # earliest k <= latest q
        if window > 0:
            live &= k_start + bk - 1 > q_start - window  # latest k inside window of earliest q

        @pl.when(live)
        def _block():
            q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
            k = k_ref[0].astype(jnp.float32)  # (BK, D)
            v = v_ref[0].astype(jnp.float32)  # (BK, D)
            s = q @ k.T  # (BQ, BK)
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= cols <= rows
            if window > 0:
                mask &= cols > rows - window
            s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
            p = jnp.exp(s - m_new[:, None])
            p = jnp.where(mask, p, 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
            acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
            m_ref[...] = m_new

        @pl.when(jk == nk - 1)
        def _finalize():
            l = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)

    return _kernel


@functools.partial(
    jax.jit, static_argnames=("window", "causal", "block_q", "block_k", "interpret")
)
def swa_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int = 0,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D). window=0 => full (causal) attention."""
    B, H, S, D = q.shape
    bq, bk = min(block_q, S), min(block_k, S)
    pad = (-S) % max(bq, bk)
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    Sp = S + pad
    qf = qp.reshape(B * H, Sp, D)
    kf = kp.reshape(B * H, Sp, D)
    vf = vp.reshape(B * H, Sp, D)
    scale = 1.0 / (D**0.5)
    grid = (B * H, Sp // bq, Sp // bk)
    out = pl.pallas_call(
        _make_kernel(scale, window, causal, bq, bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sp, D)[:, :, :S]
