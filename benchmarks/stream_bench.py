"""Streaming-scenario bench: F1 + VAoI dynamics for every data-stream
scenario × selection policy (``repro/data/stream.py``, DESIGN.md §10).

Each cell runs a short solo simulation on a micro CNN and records the final
macro-F1, the VAoI trajectory summary (mean age, mean feature distance), and
epoch throughput.  Results go to stdout CSV (the ``benchmarks/run.py``
harness protocol) AND to ``BENCH_stream.json`` at the repo root — a
machine-readable perf/correctness-trajectory file validated by
``tools/check_bench.py`` in CI.

  PYTHONPATH=src python benchmarks/stream_bench.py           # 4x5 grid, quick
  PYTHONPATH=src python benchmarks/stream_bench.py --full    # larger protocol
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import jax
import numpy as np

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_stream.json"

_MICRO = dict(image_size=8, conv_channels=(2, 2, 2, 2, 2, 2), fc_dims=(8,))

# mean-matched streaming params sized to the quick protocol's T
_STREAM_PARAMS = {
    "static": (),
    "drift": (("period", 8.0), ("alpha", 0.3)),
    "arrival": (("rate", 4.0), ("burst", 2.0), ("window", 16.0)),
    "shift": (("period", 4.0), ("num_phases", 2.0)),
}


def _world(num_clients: int, samples: int):
    from repro.configs.cifar_cnn import CNNConfig
    from repro.data import make_federated_dataset
    from repro.fl import cnn_backend

    cnn = CNNConfig(name="stream-micro", **_MICRO)
    data = make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=num_clients,
        samples_per_client=samples, alpha=0.3, test_size=64, image_size=8,
    )
    return data, cnn_backend(cnn)


def bench_one(
    scenario: str, policy: str, data, backend, epochs: int, n: int,
    compact: bool = False,
) -> dict:
    from repro.core import EHFLConfig, run_simulation

    cfg = EHFLConfig(
        num_clients=n, epochs=epochs, slots_per_epoch=8, kappa=4,
        p_bc=0.4, k=max(1, n // 4), mu=0.3, e_max=8, policy=policy,
        eval_every=epochs, probe_size=4, stream=scenario,
        stream_params=_STREAM_PARAMS[scenario],
        compact="auto" if compact else False,
    )
    t0 = time.time()
    out = run_simulation(cfg, backend, data)
    wall = time.time() - t0
    m = out["metrics"]
    return {
        "scenario": scenario,
        "policy": policy,
        "compact": compact,
        "epochs": epochs,
        "N": n,
        "f1": round(float(np.asarray(m["f1"])[-1]), 4),
        "avg_age_mean": round(float(np.asarray(m["avg_age"]).mean()), 4),
        "avg_m_mean": round(float(np.asarray(m["avg_m"]).mean()), 5),
        "n_uploaded": int(np.asarray(m["n_uploaded"]).sum()),
        "epoch_s": round(wall / epochs, 4),
        "clients_per_s": round(n * epochs / max(wall, 1e-9), 1),
    }


def _compacts(policy: str, n: int) -> tuple:
    """Row variants per cell: always dense; plus a compact row when the
    policy's slab is actually below N (fedavg auto-falls-back dense, so a
    second identical row would be noise)."""
    from repro.core import EHFLConfig
    from repro.core.policies import make_policy
    from repro.core.simulator import resolve_compact_cap

    cfg = EHFLConfig(num_clients=n, k=max(1, n // 4), policy=policy)
    spec = make_policy(policy, num_clients=n, k=cfg.k)
    return (False, True) if resolve_compact_cap(cfg, spec) else (False,)


def run(quick: bool = True) -> list:
    """benchmarks/run.py suite entry: the scenario × policy × {dense,
    compact} grid, written to BENCH_stream.json, returned as harness CSV
    rows."""
    from repro.core import STREAM_SCENARIOS
    from repro.core.policies import POLICIES

    n, samples, epochs = (16, 32, 8) if quick else (64, 64, 32)
    data, backend = _world(n, samples)
    rows = [
        bench_one(sc, pol, data, backend, epochs, n, compact=c)
        for sc in STREAM_SCENARIOS
        for pol in POLICIES
        for c in _compacts(pol, n)
    ]
    OUT.write_text(json.dumps({
        "bench": "stream",
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "cpus": os.cpu_count(),
        "quick": quick,
        "rows": rows,
    }, indent=2))
    return [
        {
            "name": f"stream/{r['scenario']}_{r['policy']}"
            + ("_compact" if r["compact"] else ""),
            "us_per_call": r["epoch_s"] * 1e6,
            "derived": f"f1={r['f1']};age={r['avg_age_mean']};m={r['avg_m_mean']}",
        }
        for r in rows
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="larger N/T protocol")
    args = ap.parse_args()
    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")
    print("name,us_per_call,derived")
    for r in run(quick=not args.full):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
