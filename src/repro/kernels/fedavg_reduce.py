"""Pallas TPU kernel: weighted FedAvg aggregation.

out[p] = sum_k weights[k] * msgs[k, p] — K client update vectors of length P
reduced into the new global.  Pure bandwidth (no MXU): tiles of (BK, BP)
stream through VMEM; the P axis is the parallel grid dim, K is reduced with a
VMEM fp32 accumulator so bf16 messages aggregate without precision loss.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(msgs_ref, w_ref, out_ref, acc_ref):
    kblk = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kblk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m = msgs_ref[...].astype(jnp.float32)  # (BK, BP)
    w = w_ref[...].astype(jnp.float32)  # (BK,)
    acc_ref[...] += jnp.sum(m * w[:, None], axis=0)

    @pl.when(kblk == nk - 1)
    def _finalize():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_k", "block_p", "interpret"))
def fedavg_reduce(
    msgs: jax.Array,
    weights: jax.Array,
    *,
    block_k: int = 64,
    block_p: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """msgs: (K, P); weights: (K,) -> (P,) fp32 weighted sum.

    Handles slab-shaped inputs (small K, e.g. the ``cap``-sized active-set
    training slab of DESIGN.md §11) as well as fleet-wide (N, P): the K
    block is rounded up to the fp32 sublane multiple of 8 so a cap of, say,
    10 tiles as one aligned (16, BP) block instead of a ragged (10, BP)
    one; zero-padded rows carry zero weight and don't touch the result."""
    K, P = msgs.shape
    bk, bp = min(block_k, -(-K // 8) * 8), min(block_p, P)
    pad_k, pad_p = (-K) % bk, (-P) % bp
    if pad_k or pad_p:
        msgs = jnp.pad(msgs, ((0, pad_k), (0, pad_p)))
        weights = jnp.pad(weights, (0, pad_k))
    Kp, Pp = K + pad_k, P + pad_p
    grid = (Pp // bp, Kp // bk)  # K innermost (reduction)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bp), lambda p, k: (k, p)),
            pl.BlockSpec((bk,), lambda p, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda p, k: (p,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bp,), jnp.float32)],
        interpret=interpret,
    )(msgs, weights)
    return out[:P]
