"""The seed-vmapped sweep engine (repro.core.run_batch, DESIGN.md §8):
batched runs must reproduce solo runs seed-for-seed, and every harvest
scenario must run end-to-end through the batched path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_batch, run_simulation
from repro.core.harvest import SCENARIOS
from repro.data import make_federated_dataset
from repro.fl import cnn_backend

TINY_CNN = CNNConfig(
    name="tiny", image_size=16, conv_channels=(4, 4, 8, 8, 8, 8), fc_dims=(32, 16)
)


@pytest.fixture(scope="module")
def tiny_world():
    key = jax.random.PRNGKey(0)
    data = make_federated_dataset(
        key, num_clients=8, samples_per_client=40, alpha=0.5, test_size=100, image_size=16
    )
    return data, cnn_backend(TINY_CNN)


def _cfg(**kw):
    base = dict(
        num_clients=8, epochs=6, slots_per_epoch=12, kappa=8, p_bc=0.6,
        k=3, mu=0.1, e_max=13, eval_every=3, probe_size=10,
    )
    base.update(kw)
    return EHFLConfig(**base)


def test_batched_seed_matches_solo(tiny_world):
    """Seed i of run_batch follows run_simulation(seed=seeds[i]) exactly:
    integer slot dynamics bit-identical, float metrics to rounding."""
    data, backend = tiny_world
    cfg = _cfg(policy="fedavg")  # selection is float-free -> exact dynamics
    out = run_batch(cfg, backend, data, seeds=[0, 5])
    mb = out["metrics"]
    for i, seed in enumerate([0, 5]):
        solo = run_simulation(dataclasses.replace(cfg, seed=seed), backend, data)
        m = solo["metrics"]
        for k in ("energy", "n_started", "n_uploaded"):
            assert (np.asarray(m[k]) == np.asarray(mb[k][i])).all(), (k, seed)
        assert (np.asarray(m["f1_epochs"]) == np.asarray(mb["f1_epochs"])).all()
        np.testing.assert_allclose(
            np.asarray(m["f1"]), np.asarray(mb["f1"][i]), atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(m["avg_age"]), np.asarray(mb["avg_age"][i]), atol=1e-4
        )


def test_seed_axis_shapes_and_liveness(tiny_world):
    """Ragged eval tail handled; metrics carry a live leading seed axis."""
    data, backend = tiny_world
    cfg = _cfg(policy="vaoi", p_bc=0.4, epochs=8, eval_every=3)  # 3+3+2
    out = run_batch(cfg, backend, data, seeds=[0, 1, 2])
    m = out["metrics"]
    assert m["energy"].shape == (3, 8)
    assert m["f1"].shape == (3, 3)
    assert list(np.asarray(m["f1_epochs"])) == [3, 6, 8]
    assert m["total_energy"].shape == (3,)
    energy = np.asarray(m["energy"])
    assert not (energy[0] == energy[1]).all() or not (energy[1] == energy[2]).all()


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_all_scenarios_run_batched(scenario, tiny_world):
    data, backend = tiny_world
    cfg = _cfg(policy="vaoi", harvest=scenario)
    out = run_batch(cfg, backend, data, seeds=[0, 1])
    m = out["metrics"]
    assert np.isfinite(np.asarray(m["f1"])).all()
    assert float(np.asarray(m["total_energy"]).min()) >= 0
    # energy accounting holds under every arrival process
    assert (np.asarray(m["energy"]).sum(-1) >= cfg.kappa * np.asarray(m["n_started"]).sum(-1)).all()


def test_scenarios_through_run_simulation(tiny_world):
    """The solo path accepts scenarios too (persistent state across epochs)."""
    data, backend = tiny_world
    out = run_simulation(_cfg(policy="vaoi", harvest="markov"), backend, data)
    assert np.isfinite(np.asarray(out["metrics"]["f1"])).all()


def test_bernoulli_scenario_reproduces_seed_behavior(tiny_world):
    """harvest='bernoulli' (the default) is the exact seed code path: same
    trajectories as an identical config spelled the legacy way."""
    data, backend = tiny_world
    cfg = _cfg(policy="vaoi")
    assert cfg.harvest == "bernoulli"
    a = run_simulation(cfg, backend, data)
    b = run_simulation(dataclasses.replace(cfg, harvest="bernoulli"), backend, data)
    for k in ("energy", "n_started", "f1", "avg_age"):
        assert (np.asarray(a["metrics"][k]) == np.asarray(b["metrics"][k])).all()
