"""Scheduling policies (§V benchmarks + the paper's VAoI scheme).

Each policy supplies, per epoch:
  * ``select(age, key) -> (N,) bool`` — who *wants* to train this epoch;
  * ``want_fn(selected)`` — slot-level start rule for the energy scan;
  * whether it maintains VAoI state (only the paper's scheme does).

Policies:
  vaoi          — the paper: top-k by VAoI, start ASAP within the epoch.
  vaoi_soft     — beyond-paper ablation: Gumbel-top-k selection
                  (``vaoi.select_gumbel``) samples k clients WITHOUT
                  replacement with probability proportional to normalized
                  age, instead of Alg. 2's deterministic top-k.  Identical
                  slot-level behavior to ``vaoi`` otherwise; it adds
                  exploration under age ties (cold start, saturated ages).
  fedavg        — greedy energy-aware baseline: everyone, ASAP.
  fedbacys      — cyclic groups; procrastinate to the last feasible slot.
  fedbacys_odd  — FedBacys + odd-chance rule (skip every other opportunity).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import vaoi as vaoi_lib
from repro.core.energy import SlotState

POLICIES = ("vaoi", "vaoi_soft", "fedavg", "fedbacys", "fedbacys_odd")


@dataclass(frozen=True)
class PolicySpec:
    name: str
    uses_vaoi: bool
    cyclic_groups: int = 0  # FedBacys group count G (0 = none)
    # static upper bound on the number of clients that can START training in
    # any single epoch (0 = no bound below N).  Starters are a subset of the
    # epoch_selection mask, so this is the selection mask's max popcount:
    # k for the top-k schemes, the largest cyclic group for FedBacys, N for
    # fedavg.  The active-set compaction path (simulator.epoch_body,
    # DESIGN.md §11) sizes its training slab with it.
    max_active: int = 0


def make_policy(name: str, *, num_clients: int, k: int, num_groups: int = 0) -> PolicySpec:
    if name not in POLICIES:
        raise ValueError(f"unknown policy {name!r}; known: {POLICIES}")
    if name in ("fedbacys", "fedbacys_odd") and num_groups == 0:
        num_groups = max(1, num_clients // max(k, 1))
    if name in ("vaoi", "vaoi_soft"):
        max_active = min(k, num_clients)  # Alg. 2 selects exactly k
    elif name in ("fedbacys", "fedbacys_odd"):
        # group g = {i : i mod G == g}; the largest has ceil(N/G) members
        max_active = -(-num_clients // max(1, num_groups))
    else:  # fedavg schedules everyone
        max_active = num_clients
    return PolicySpec(
        name=name,
        uses_vaoi=name.startswith("vaoi"),
        cyclic_groups=num_groups,
        max_active=max_active,
    )


def epoch_selection(
    spec: PolicySpec,
    age: jax.Array,
    epoch: jax.Array,
    k: int,
    key: jax.Array,
) -> jax.Array:
    """(N,) mask of clients scheduled for this epoch."""
    n = age.shape[0]
    if spec.name == "vaoi":
        return vaoi_lib.select_topk(age, k, key)
    if spec.name == "vaoi_soft":
        return vaoi_lib.select_gumbel(age, k, key)
    if spec.name == "fedavg":
        return jnp.ones((n,), bool)
    # FedBacys variants: group g participates in epoch t iff g == t mod G
    G = spec.cyclic_groups
    groups = jnp.arange(n) % G
    return groups == (epoch % G)


def epoch_selection_sharded(
    spec: PolicySpec,
    age: jax.Array,
    epoch: jax.Array,
    k: int,
    key: jax.Array,
    *,
    axis_name: str,
    n_global: int,
) -> jax.Array:
    """:func:`epoch_selection` with the client axis sharded over ``axis_name``
    (DESIGN.md §9): ``age`` is the local (N_loc,) shard, the returned mask is
    local too, and the selection matches the single-device path bit-for-bit.
    """
    n_loc = age.shape[0]
    if spec.name == "vaoi":
        return vaoi_lib.select_topk_sharded(age, k, key, axis_name=axis_name, n_global=n_global)
    if spec.name == "vaoi_soft":
        return vaoi_lib.select_gumbel_sharded(age, k, key, axis_name=axis_name, n_global=n_global)
    if spec.name == "fedavg":
        return jnp.ones((n_loc,), bool)
    # FedBacys variants: the cyclic group id is a *global* client index mod G,
    # so the local arange is offset by this shard's position in the fleet
    G = spec.cyclic_groups
    off = jax.lax.axis_index(axis_name) * n_loc
    groups = (off + jnp.arange(n_loc)) % G
    return groups == (epoch % G)


def make_want_fn(
    spec: PolicySpec, selected: jax.Array, S: int, kappa: int
) -> Callable[[jax.Array, SlotState], jax.Array]:
    """Slot-level 'wants to start training now' rule."""
    last = S - kappa

    if spec.name in ("vaoi", "vaoi_soft", "fedavg"):
        # start as soon as feasible within the epoch
        def want(s, st: SlotState):
            return selected

        return want

    if spec.name == "fedbacys":
        def want(s, st: SlotState):
            return selected & (s == last)

        return want

    # fedbacys_odd: also require an odd opportunity counter (counter is
    # incremented by count_opportunity_fn before this is evaluated)
    def want(s, st: SlotState):
        return selected & (s == last) & (st.counter % 2 == 1)

    return want


def make_opportunity_fn(
    spec: PolicySpec, selected: jax.Array, S: int, kappa: int
) -> Optional[Callable[[jax.Array, SlotState], jax.Array]]:
    """FedBacys-Odd: opportunities = slots where criteria (i)-(iii) are met."""
    if spec.name != "fedbacys_odd":
        return None
    last = S - kappa

    def opp(s, st: SlotState):
        return (
            selected
            & (s == last)
            & (~st.started)
            & (~st.pending)
            & (st.battery >= kappa)
        )

    return opp
