"""Scheduling-policy semantics (Alg. 2 + §V baselines)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policies


def test_policy_registry():
    for name in policies.POLICIES:
        spec = policies.make_policy(name, num_clients=100, k=10)
        assert spec.name == name
    with pytest.raises(ValueError):
        policies.make_policy("nope", num_clients=10, k=2)


def test_fedbacys_group_cycling():
    spec = policies.make_policy("fedbacys", num_clients=100, k=10)
    assert spec.cyclic_groups == 10
    age = jnp.zeros((100,))
    sel_t0 = policies.epoch_selection(spec, age, jnp.asarray(0), 10, jax.random.PRNGKey(0))
    sel_t1 = policies.epoch_selection(spec, age, jnp.asarray(1), 10, jax.random.PRNGKey(0))
    sel_t10 = policies.epoch_selection(spec, age, jnp.asarray(10), 10, jax.random.PRNGKey(0))
    assert int(sel_t0.sum()) == 10
    assert not bool(jnp.any(sel_t0 & sel_t1))  # disjoint groups
    np.testing.assert_array_equal(sel_t0, sel_t10)  # cycle length G


def test_fedavg_selects_everyone():
    spec = policies.make_policy("fedavg", num_clients=7, k=3)
    sel = policies.epoch_selection(spec, jnp.zeros((7,)), jnp.asarray(4), 3, jax.random.PRNGKey(1))
    assert bool(jnp.all(sel))


def test_want_fn_timing():
    S, kappa = 30, 20
    sel = jnp.ones((4,), bool)
    from repro.core.energy import SlotState

    st = SlotState(
        battery=jnp.full((4,), 25, jnp.int32),
        started=jnp.zeros((4,), bool),
        start_slot=jnp.full((4,), S, jnp.int32),
        pending=jnp.zeros((4,), bool),
        uploaded=jnp.zeros((4,), bool),
        counter=jnp.ones((4,), jnp.int32),
        energy_used=jnp.zeros((4,), jnp.int32),
        key=jax.random.PRNGKey(0),
    )
    greedy = policies.make_want_fn(policies.make_policy("fedavg", num_clients=4, k=4), sel, S, kappa)
    assert bool(jnp.all(greedy(jnp.asarray(0), st)))
    bacys = policies.make_want_fn(policies.make_policy("fedbacys", num_clients=4, k=4), sel, S, kappa)
    assert not bool(jnp.any(bacys(jnp.asarray(0), st)))  # procrastinates
    assert bool(jnp.all(bacys(jnp.asarray(S - kappa), st)))  # last feasible slot
    odd = policies.make_want_fn(
        policies.make_policy("fedbacys_odd", num_clients=4, k=4), sel, S, kappa
    )
    assert bool(jnp.all(odd(jnp.asarray(S - kappa), st)))  # counter=1 (odd) -> train
    st_even = st._replace(counter=jnp.zeros((4,), jnp.int32))
    assert not bool(jnp.any(odd(jnp.asarray(S - kappa), st_even)))  # even -> skip


def test_fedbacys_odd_skips_every_other_opportunity():
    """Integration: with p_bc=1 (always-charged), fedbacys trains every cycle,
    fedbacys_odd every other cycle."""
    from repro.core import energy as energy_lib

    def run_epochs(policy_name, epochs=6):
        n, S, kappa = 4, 45, 20
        spec = policies.make_policy(policy_name, num_clients=n, k=n, num_groups=1)
        battery = jnp.full((n,), 25, jnp.int32)
        pending = jnp.zeros((n,), bool)
        counter = jnp.zeros((n,), jnp.int32)
        key = jax.random.PRNGKey(0)
        starts = []
        for t in range(epochs):
            key, ks = jax.random.split(key)
            sel = policies.epoch_selection(spec, jnp.zeros((n,)), jnp.asarray(t), n, ks)
            st0 = energy_lib.SlotState(
                battery=battery, started=jnp.zeros((n,), bool),
                start_slot=jnp.full((n,), S, jnp.int32), pending=pending,
                uploaded=jnp.zeros((n,), bool), counter=counter,
                energy_used=jnp.zeros((n,), jnp.int32), key=ks,
            )
            st = energy_lib.scan_epoch(
                st0, S=S, kappa=kappa, p_bc=1.0, e_max=25,
                want_fn=policies.make_want_fn(spec, sel, S, kappa),
                count_opportunity_fn=policies.make_opportunity_fn(spec, sel, S, kappa),
            )
            battery, pending, counter = st.battery, st.pending, st.counter
            starts.append(int(st.started.sum()))
        return starts

    assert run_epochs("fedbacys") == [4, 4, 4, 4, 4, 4]
    # odd-chance rule: counter hits 1 (odd -> train), then 2 (skip), ...
    assert run_epochs("fedbacys_odd") == [4, 0, 4, 0, 4, 0]
