"""Whisper-large-v3 [arXiv:2212.04356] — enc-dec transformer; mel+conv frontend STUBBED
(input_specs provides (B, 1500, d_model) frame embeddings — the carve-out)."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,  # decoder layers
        num_encoder_layers=32,
        is_encoder_decoder=True,
        encoder_seq=1500,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        use_rope=False,  # learned absolute positions
        act="gelu",
        norm="layernorm",
        qkv_bias=True,
        attn_out_bias=True,
        dtype=jnp.bfloat16,
        source="arXiv:2212.04356",
    )
)
