"""Benchmark harness — one module per paper table/figure + infra rooflines.

Prints ``name,us_per_call,derived`` CSV.  Default is the quick protocol
(CPU-feasible, same structural constants as the paper); ``--full`` runs the
3x3 (alpha x p_bc) grid at larger N/T.

The ``fleet`` suite additionally writes the machine-readable
``BENCH_fleet.json`` perf-trajectory file at the repo root (sharded-fleet
epoch throughput over N; run ``benchmarks/fleet_bench.py`` standalone to
sweep on 8 virtual host devices).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only",
        default=None,
        help="comma list from: fig4,fig5,fig6,roofline,kernels,ablation,fleet,stream",
    )
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        ablation_mu, fig4_f1, fig5_vaoi, fig6_energy, fleet_bench, kernels_bench,
        roofline, stream_bench,
    )

    suites = {
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
        "fig4": fig4_f1.run,
        "fig5": fig5_vaoi.run,
        "fig6": fig6_energy.run,
        "ablation": ablation_mu.run,
        "fleet": fleet_bench.run,
        "stream": stream_bench.run,
    }
    wanted = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    failed = []
    for name in wanted:
        if name not in suites:
            print(f"{name}/ERROR,0,UnknownSuite", file=sys.stderr)
            failed.append(name)
            continue
        t0 = time.time()
        try:
            rows = suites[name](quick=quick)
        except Exception as e:  # keep the harness going, but record the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            failed.append(name)
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        print(f"{name}/_suite_wall,{(time.time()-t0)*1e6:.0f},ok", file=sys.stderr)
    if failed:
        # CI gates on this: a broken suite must fail the job, not exit 0
        print(f"FAILED suites: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
