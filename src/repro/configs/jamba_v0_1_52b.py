"""Jamba-v0.1-52B [arXiv:2403.19887] — Mamba+attention 7:1 interleave, MoE 16e top-2 every 2nd layer."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        num_experts=16,
        experts_per_token=2,
        moe_period=2,
        moe_offset=1,
        attn_period=8,  # 1 attention layer per 8 (7 mamba : 1 attn)
        attn_offset=4,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        use_rope=False,  # Jamba attention has no positional encoding
        dtype=jnp.bfloat16,
        source="arXiv:2403.19887",
    )
)
