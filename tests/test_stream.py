"""Streaming non-IID data engine (repro/data/stream.py, DESIGN.md §10).

Contracts under test:
  * the ``static`` stream is the frozen-partition seed behavior BIT-FOR-BIT
    (no state, no PRNG consumption, identity view — the trajectory equals an
    epoch body with the stream machinery removed entirely);
  * per-scenario invariants: rotating label marginals for ``drift``, window
    occupancy/freshness for ``arrival``, scheduled class swaps for ``shift``;
  * the sharded stream (``make_sharded_stream``) is bit-identical to the
    solo stream — the fleet global-draw-and-slice contract (rerun on 8
    virtual devices by the CI multi-device leg);
  * every scenario runs end to end through ``run_simulation``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_simulation
from repro.core import policies as policy_lib
from repro.core.simulator import epoch_body, init_carry, make_epoch_fn, solo_ops
from repro.data import make_federated_dataset
from repro.data import stream as stream_lib
from repro.fl import cnn_backend
from repro.launch.mesh import make_fleet_mesh

TINY_CNN = CNNConfig(
    name="tiny", image_size=16, conv_channels=(4, 4, 8, 8, 8, 8), fc_dims=(32, 16)
)


@pytest.fixture(scope="module")
def backend():
    return cnn_backend(TINY_CNN)


@pytest.fixture(scope="module")
def world():
    return make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=8, samples_per_client=40,
        alpha=0.5, test_size=100, image_size=16,
    )


def _cfg(**kw):
    base = dict(
        num_clients=8, epochs=4, slots_per_epoch=12, kappa=8, p_bc=0.8,
        k=3, mu=0.1, e_max=13, eval_every=4, probe_size=10,
    )
    base.update(kw)
    return EHFLConfig(**base)


def _balanced_labels(n_clients: int, n_pool: int, num_classes: int = 10) -> jax.Array:
    """Every client holds an equal slice of every class."""
    return jnp.tile(jnp.arange(n_pool, dtype=jnp.int32) % num_classes, (n_clients, 1))


def _roll(stream, labels, steps, key=None, n=None):
    """Init + step a stream for ``steps`` epochs; returns (idx list, states)."""
    key = jax.random.PRNGKey(7) if key is None else key
    state = stream.init(key, labels.shape[0] if n is None else n)
    idxs, states = [], []
    for t in range(steps):
        idx, state = stream.step(state, jnp.asarray(t, jnp.int32), labels)
        idxs.append(idx)
        states.append(state)
    return idxs, states


def _marginal(labels, idx, num_classes=10):
    view = np.asarray(jnp.take_along_axis(labels, idx, axis=1)).ravel()
    return np.bincount(view, minlength=num_classes) / view.size


# ---------------------------------------------------------------------------
# static: the frozen partition, bit-for-bit
# ---------------------------------------------------------------------------


def test_static_stream_is_stateless_and_keyless(world, backend):
    st = stream_lib.make_stream("static")
    assert not st.persistent
    assert st.init(jax.random.PRNGKey(0), 8) is None
    idx, state = st.step(None, jnp.asarray(0), _balanced_labels(4, 20))
    assert idx is None and state is None
    # init_carry consumes no stream key: the carry key chain equals the
    # pre-stream chain (PRNGKey -> split -> k_run, bernoulli harvest adds
    # no split either)
    cfg = _cfg()
    carry = init_carry(cfg, backend)
    _, k_run = jax.random.split(jax.random.PRNGKey(cfg.seed))
    np.testing.assert_array_equal(np.asarray(carry.key), np.asarray(k_run))
    assert carry.stream is None


def test_static_bitmatches_seed_epoch_body(world, backend):
    """The full static-stream trajectory equals an epoch body with the
    stream machinery REMOVED (stream=None) — i.e., the seed run_simulation
    path — bit for bit: metrics AND final parameters."""
    cfg = _cfg(policy="vaoi")
    assert cfg.stream == "static"  # the default IS the paper protocol
    epoch_fn = make_epoch_fn(cfg, backend, world)
    spec = policy_lib.make_policy(cfg.policy, num_clients=cfg.num_clients, k=cfg.k)
    seed_fn = lambda c, t: epoch_body(
        c, t, world["images"], world["labels"],
        cfg=cfg, backend=backend, spec=spec, process=cfg.harvest_process(),
        ops=solo_ops(cfg), stream=None,
    )
    ts = jnp.arange(cfg.epochs)
    carry_a, ms_a = jax.jit(lambda c: jax.lax.scan(epoch_fn, c, ts))(init_carry(cfg, backend))
    carry_b, ms_b = jax.jit(lambda c: jax.lax.scan(seed_fn, c, ts))(init_carry(cfg, backend))
    for k in ms_a:
        np.testing.assert_array_equal(np.asarray(ms_a[k]), np.asarray(ms_b[k]), err_msg=k)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        carry_a.global_params, carry_b.global_params,
    )


# ---------------------------------------------------------------------------
# drift: rotating label mixtures
# ---------------------------------------------------------------------------


def test_rotate_mixture_is_periodic_and_shifts():
    pi = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.full((10,), 0.5), (4,))
    period = 20.0
    at = lambda t: stream_lib.rotate_mixture(pi, jnp.asarray(t, jnp.int32), period)
    np.testing.assert_allclose(np.asarray(at(0)), np.asarray(pi), atol=1e-6)
    np.testing.assert_allclose(np.asarray(at(20)), np.asarray(pi), atol=1e-6)
    # one integer class shift (t = period / C) is a circular roll
    np.testing.assert_allclose(
        np.asarray(at(2)), np.asarray(jnp.roll(pi, 1, axis=1)), atol=1e-6
    )
    # every rotation is still a distribution
    np.testing.assert_allclose(np.asarray(at(7)).sum(axis=1), 1.0, atol=1e-5)


def test_drift_label_marginal_rotates():
    labels = _balanced_labels(4, 400)
    stream = stream_lib.make_stream("drift", period=8.0, alpha=0.3)
    idxs, _ = _roll(stream, labels, 9)
    m0, m4, m8 = (_marginal(labels, idxs[t]) for t in (0, 4, 8))
    # half a period away the mixture has rotated C/2 classes: the view
    # marginal moves by a substantial total-variation distance...
    assert 0.5 * np.abs(m0 - m4).sum() > 0.2
    # ...and a full period later it is back (same mixture, fresh noise)
    assert 0.5 * np.abs(m0 - m8).sum() < 0.5 * np.abs(m0 - m4).sum()
    # idx maps stay within the pool
    for idx in idxs:
        assert int(idx.min()) >= 0 and int(idx.max()) < labels.shape[1]


# ---------------------------------------------------------------------------
# arrival: sliding-window sample arrivals
# ---------------------------------------------------------------------------


def test_arrival_window_occupancy_and_freshness():
    n_clients, n_pool, window = 8, 32, 12
    labels = _balanced_labels(n_clients, n_pool)
    stream = stream_lib.make_stream("arrival", rate=3.0, burst=2.0, window=window)
    idxs, states = _roll(stream, labels, 25)
    prev = np.ones((n_clients,), np.int64)  # warm = 1
    for idx, (count, _key) in zip(idxs, states):
        count = np.asarray(count)
        occ = np.asarray(stream_lib.arrival_occupancy(jnp.asarray(count), window, n_pool))
        assert (count >= prev).all()  # arrivals only accumulate
        assert (occ >= 1).all() and (occ <= window).all()
        for i in range(n_clients):
            seen = set(np.asarray(idx[i]).tolist())
            # the view covers EXACTLY the occupied window: the occ most
            # recent arrivals (stream position mod pool), nothing else
            want = {int((count[i] - 1 - j) % n_pool) for j in range(occ[i])}
            assert seen == want
        prev = count
    # mean arrivals/epoch tracks the configured rate (generous statistical
    # band; 8 clients x 25 epochs)
    total = float(np.asarray(states[-1][0]).sum() - n_clients)
    mean_rate = total / (n_clients * len(idxs))
    assert 1.5 < mean_rate < 4.5


def test_arrival_full_pool_window_defaults():
    labels = _balanced_labels(2, 16)
    stream = stream_lib.make_stream("arrival", rate=100.0)  # saturate fast
    idxs, states = _roll(stream, labels, 8)
    count = np.asarray(states[-1][0])
    assert (count > 16).all()  # wrapped: stream longer than the pool
    # saturated window == whole pool: the view is a permutation of the pool
    assert [sorted(np.asarray(idxs[-1][i]).tolist()) for i in range(2)] == [
        list(range(16))
    ] * 2


# ---------------------------------------------------------------------------
# shift: class-incremental swaps
# ---------------------------------------------------------------------------


def test_shift_swaps_active_classes_on_schedule():
    labels = _balanced_labels(4, 200)
    period, phases = 4, 2
    stream = stream_lib.make_stream("shift", period=period, num_phases=phases)
    idxs, _ = _roll(stream, labels, 2 * period)
    for t, idx in enumerate(idxs):
        phase = (t // period) % phases
        view = np.asarray(jnp.take_along_axis(labels, idx, axis=1))
        groups = np.asarray(stream_lib.class_group(jnp.asarray(view), phases, 10))
        assert (groups == phase).all(), f"epoch {t}: classes outside phase {phase}"
    # the swap happens exactly at the period boundary
    m_before = _marginal(labels, idxs[period - 1])
    m_after = _marginal(labels, idxs[period])
    assert m_before[:5].sum() > 0.99 and m_after[5:].sum() > 0.99


def test_shift_uniform_fallback_when_no_active_samples():
    # client 0 holds ONLY class 0 (group 0): at phase 1 it has no active
    # samples and falls back to a uniform view of its pool
    labels = jnp.zeros((1, 50), jnp.int32)
    stream = stream_lib.make_stream("shift", period=1, num_phases=2)
    idxs, _ = _roll(stream, labels, 2)
    idx = np.asarray(idxs[1])  # t=1 -> phase 1, nothing active
    assert idx.min() >= 0 and idx.max() < 50
    assert len(set(idx.ravel().tolist())) > 10  # spread, not a constant fill


# ---------------------------------------------------------------------------
# sharded == solo (the fleet contract, DESIGN.md §9/§10)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", ["drift", "arrival", "shift"])
def test_sharded_stream_matches_solo(scenario):
    """make_sharded_stream draws are bit-identical to the solo stream under
    shard_map — init state AND every per-epoch idx map."""
    n, n_pool, steps = 16, 24, 5
    params = {"drift": {"period": 6.0}, "arrival": {"rate": 2.5, "window": 8.0},
              "shift": {"period": 2.0}}[scenario]
    mesh = make_fleet_mesh(num_clients=n)
    labels = _balanced_labels(n, n_pool)
    solo = stream_lib.make_stream(scenario, **params)
    shp = stream_lib.make_sharded_stream(
        scenario, axis_name="data", n_global=n, **params
    )
    key = jax.random.PRNGKey(11)

    def roll(stream, lbls):
        state = stream.init(key, lbls.shape[0])
        out = []
        for t in range(steps):
            idx, state = stream.step(state, jnp.asarray(t, jnp.int32), lbls)
            out.append(idx)
        return jnp.stack(out)

    want = roll(solo, labels)
    got = jax.jit(
        shard_map(
            lambda l: roll(shp, l), mesh=mesh, in_specs=P("data", None),
            out_specs=P(None, "data", None), check_rep=False,
        )
    )(labels)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got), err_msg=scenario)


# ---------------------------------------------------------------------------
# end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", stream_lib.SCENARIOS)
def test_streams_run_end_to_end(scenario, world, backend):
    cfg = _cfg(epochs=2, eval_every=2, stream=scenario)
    out = run_simulation(cfg, backend, world)
    m = out["metrics"]
    assert np.isfinite(np.asarray(m["f1"])).all()
    assert np.isfinite(np.asarray(m["avg_m"])).all()
    assert float(m["total_energy"]) >= 0


def test_backend_num_classes_threads_into_streams():
    """Class-conditioned streams pick up the dataset's class count from the
    backend (an explicit stream_params entry wins); 20-class labels must not
    be clamped into a 10-class mixture."""
    cfg = EHFLConfig(stream="drift")
    pi, _key = cfg.data_stream(num_classes=20).init(jax.random.PRNGKey(0), 4)
    assert pi.shape == (4, 20)
    cfg_explicit = dataclasses.replace(
        cfg, stream_params=(("num_classes", 5.0),)
    )
    pi5, _key = cfg_explicit.data_stream(num_classes=20).init(jax.random.PRNGKey(0), 4)
    assert pi5.shape == (4, 5)
    # shift: all 20 class groups cycle through the active phases
    labels = _balanced_labels(2, 400, num_classes=20)
    st = stream_lib.make_stream("shift", period=1, num_phases=2, num_classes=20)
    idxs, _ = _roll(st, labels, 2)
    seen = set()
    for t, idx in enumerate(idxs):
        view = np.asarray(jnp.take_along_axis(labels, idx, axis=1))
        seen |= set(view.ravel().tolist())
    assert seen == set(range(20))  # classes 10-19 are NOT silently excluded


def test_unknown_stream_raises(world, backend):
    with pytest.raises(ValueError):
        stream_lib.make_stream("nope")
    cfg = dataclasses.replace(_cfg(), stream="nope")
    with pytest.raises(ValueError):
        run_simulation(cfg, backend, world)
