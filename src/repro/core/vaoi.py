"""Version Age of Information (VAoI) — Eq. (2)/(7) of the paper, plus the
feature-based dissimilarity proxy M_i (Eq. 5) and Alg. 2 client selection.

All functions are pure jnp (the Pallas kernel in ``repro.kernels`` is the
TPU-optimized fused version of :func:`vaoi_update`; ``tests/test_kernels.py``
asserts they agree).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def feature_distance(v: jax.Array, h: jax.Array) -> jax.Array:
    """M_i = ||v_i - h_i||_2 per client. v, h: (N, F) -> (N,)."""
    diff = v.astype(jnp.float32) - h.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


def vaoi_update(age: jax.Array, m: jax.Array, q: jax.Array, mu: float) -> jax.Array:
    """Eq. (7): X(t+1) = (X+1)(1-q) if M >= mu else X(1-q).

    age: (N,) float; m: (N,) distances; q: (N,) {0,1} participation.
    """
    inc = jnp.where(m >= mu, age + 1.0, age)
    return inc * (1.0 - q.astype(age.dtype))


def select_topk(age: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """Alg. 2: normalize p_i = X_i / sum X_j, take the k largest.

    Random tie-breaking (also covers the all-zero cold start, where selection
    degenerates to uniform sampling of k clients). Returns a boolean mask (N,).
    """
    n = age.shape[0]
    noise = jax.random.uniform(key, (n,), minval=0.0, maxval=1e-3)
    total = jnp.sum(age)
    p = jnp.where(total > 0, age / jnp.maximum(total, 1e-12), 0.0)
    scores = p + noise
    _, idx = jax.lax.top_k(scores, k)
    return jnp.zeros((n,), bool).at[idx].set(True)


def select_gumbel(age: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """Sample k clients WITHOUT replacement with probability proportional to
    p_i = X_i / sum X_j (Gumbel-top-k).  A stochastic variant of Alg. 2's
    deterministic top-k (beyond-paper ablation: exploration under ties)."""
    n = age.shape[0]
    logp = jnp.where(age > 0, jnp.log(jnp.maximum(age, 1e-12)), -20.0)
    g = jax.random.gumbel(key, (n,))
    _, idx = jax.lax.top_k(logp + g, k)
    return jnp.zeros((n,), bool).at[idx].set(True)


def client_select(
    age: jax.Array,
    v: jax.Array,
    h: jax.Array,
    k: int,
    mu: float,
    key: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Alg. 2 CLIENTSELECT: returns (selected mask, new ages, distances M).

    v: (N, F) feature vectors of the *global* model on each client's probe
    batch (one forward pass, line 7); h: (N, F) stored historical moments.
    """
    selected = select_topk(age, k, key)
    m = feature_distance(v, h)
    q = selected.astype(jnp.float32)
    new_age = vaoi_update(age, m, q, mu)
    return selected, new_age, m
