import jax
import pytest

try:  # the property suites want hypothesis; fall back to the deterministic
    import hypothesis  # noqa: F401  # stub when it isn't installed
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
