"""Lossy-uplink channel bench: delivery/retry dynamics for every channel
scenario × selection policy (``repro/core/channel.py``, DESIGN.md §12).

Each cell runs a short solo simulation on the stream-bench micro world and
records the final macro-F1, the VAoI trajectory summary, the uplink outcome
counters (delivery rate, retries, drops), and epoch throughput.  Results go
to stdout CSV (the ``benchmarks/run.py`` harness protocol) AND to
``BENCH_channel.json`` at the repo root, validated by ``tools/check_bench.py``
in CI — including the contract that the ``ideal`` rows BIT-MATCH the
``BENCH_stream.json`` static cells (same world, same protocol constants:
the ideal channel is the pre-channel simulator).

The lossy axes sweep the knobs that matter per scenario: the erasure rows
sweep ``p_loss``, the ALOHA rows sweep ``num_channels`` (contention), the
fading row exercises the Gilbert–Elliott burst regime.

  PYTHONPATH=src python benchmarks/channel_bench.py           # quick grid
  PYTHONPATH=src python benchmarks/channel_bench.py --full    # larger protocol
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import jax
import numpy as np

try:  # harness mode (python -m benchmarks.run) vs script mode
    from benchmarks import stream_bench
except ImportError:  # script mode: benchmarks/ itself is sys.path[0]
    import stream_bench

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_channel.json"


def bench_one(
    channel: str, params: tuple, policy: str, data, backend, epochs: int,
    n: int, compact: bool = False,
) -> dict:
    from repro.core import EHFLConfig, run_simulation

    # the stream-bench quick protocol constants, verbatim: ideal rows must
    # bit-match the BENCH_stream static cells (check_bench enforces this)
    cfg = EHFLConfig(
        num_clients=n, epochs=epochs, slots_per_epoch=8, kappa=4,
        p_bc=0.4, k=max(1, n // 4), mu=0.3, e_max=8, policy=policy,
        eval_every=epochs, probe_size=4,
        channel=channel, channel_params=params,
        compact="auto" if compact else False,
    )
    t0 = time.time()
    out = run_simulation(cfg, backend, data)
    wall = time.time() - t0
    m = out["metrics"]
    uploaded = int(np.asarray(m["n_uploaded"]).sum())
    delivered = int(np.asarray(m["n_delivered"]).sum())
    return {
        "scenario": channel,
        "params": dict(params),
        "policy": policy,
        "compact": compact,
        "epochs": epochs,
        "N": n,
        "f1": round(float(np.asarray(m["f1"])[-1]), 4),
        "avg_age_mean": round(float(np.asarray(m["avg_age"]).mean()), 4),
        "avg_m_mean": round(float(np.asarray(m["avg_m"]).mean()), 5),
        "n_uploaded": uploaded,
        "delivery_rate": round(delivered / max(uploaded, 1), 4),
        "retries": int(np.asarray(m["n_failed"]).sum()),
        "drops": int(np.asarray(m["n_dropped"]).sum()),
        "epoch_s": round(wall / epochs, 4),
        "clients_per_s": round(n * epochs / max(wall, 1e-9), 1),
    }


def _grid(n: int) -> list:
    """(channel, params, policy, compact) cells: ideal × every policy (the
    bit-match anchor rows, dense + compact like the stream bench), a
    loss-rate sweep on erasure, a contention sweep on ALOHA, and the bursty
    fading regime."""
    from repro.core.policies import POLICIES

    cells = [
        ("ideal", (), pol, c)
        for pol in POLICIES
        for c in stream_bench._compacts(pol, n)
    ]
    cells += [
        ("erasure", (("p_loss", p),), "vaoi", False) for p in (0.2, 0.5, 0.8)
    ]
    cells += [
        ("aloha", (("num_channels", float(M)),), "vaoi", False) for M in (1, 2, 4)
    ]
    cells += [
        ("fading", (("p_bad", 0.4), ("sojourn", 2.0)), "vaoi", False),
        ("erasure", (("p_loss", 0.3), ("concentration", 1.0)), "fedbacys", False),
    ]
    return cells


def run(quick: bool = True) -> list:
    """benchmarks/run.py suite entry: the channel grid, written to
    BENCH_channel.json, returned as harness CSV rows."""
    n, samples, epochs = (16, 32, 8) if quick else (64, 64, 32)
    data, backend = stream_bench._world(n, samples)
    rows = [
        bench_one(ch, params, pol, data, backend, epochs, n, compact=c)
        for ch, params, pol, c in _grid(n)
    ]
    OUT.write_text(json.dumps({
        "bench": "channel",
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "cpus": os.cpu_count(),
        "quick": quick,
        "rows": rows,
    }, indent=2))
    return [
        {
            "name": f"channel/{r['scenario']}_{r['policy']}"
            + "".join(f"_{k}{v:g}" for k, v in r["params"].items())
            + ("_compact" if r["compact"] else ""),
            "us_per_call": r["epoch_s"] * 1e6,
            "derived": f"f1={r['f1']};deliv={r['delivery_rate']}"
            f";retries={r['retries']};drops={r['drops']}",
        }
        for r in rows
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="larger N/T protocol")
    args = ap.parse_args()
    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")
    print("name,us_per_call,derived")
    for r in run(quick=not args.full):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
