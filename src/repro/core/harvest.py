"""Harvest-scenario library — pluggable energy-arrival processes (DESIGN.md §7).

The paper's energy model (§III-C, Eq. 3/4) is a homogeneous Bernoulli arrival
with one scalar ``p_bc``.  Robustness claims about semantics-aware scheduling
only bite under *realistic* energy: bursty (Markovian), time-varying
(solar/diurnal), and heterogeneous (per-client rates).  This module factors
the arrival process out of ``repro.core.energy`` behind a tiny stateful
protocol so every scenario runs through the same slot-level dynamics:

  * ``init(key, n) -> state``      — build the per-simulation process state;
  * ``step(state, battery) -> (charge, state)`` — one slot: ``charge`` is an
    ``(N,)`` int32 vector of arriving energy units (0/1 per the paper's
    unit-quantized model); capping at ``e_max`` stays in the battery code.

``persistent`` distinguishes processes whose state must survive across epochs
(Markov phase, diurnal clock, heterogeneous rates — threaded through the
simulator's ``EpochCarry``) from the memoryless Bernoulli default, which is
re-seeded per epoch from the slot-scan key exactly as the seed code did —
keeping the default scenario bit-identical to the original ``harvest_step``
chain.

The streaming-data engine (``repro/data/stream.py``, DESIGN.md §10) is this
protocol's sibling on the data axis: per-epoch data views instead of
per-slot energy arrivals, same init/step + persistent-state design and the
same global-draw-and-slice sharded forms.

Scenarios (all parameterized so the long-run mean arrival rate is ``p_bc``,
making cross-scenario comparisons energy-neutral):

  bernoulli  — i.i.d. arrivals w.p. ``p_bc`` (paper-faithful default).
  markov     — Gilbert–Elliott ON/OFF bursts: arrivals w.p. ``p_on`` while
               ON, none while OFF; ``sojourn`` sets the phase-relaxation
               timescale (mean ON sojourn is sojourn/(1-pi), OFF sojourn
               sojourn/pi for stationary ON-fraction pi = p_bc/p_on).
  diurnal    — deterministic solar-like half-sine intensity over a ``period``
               slot day (daylight fraction ``day_frac``) × Bernoulli
               thinning; peak/daylight-width/base are renormalized so the
               day-averaged rate is exactly ``p_bc`` for any p_bc.
  hetero     — static per-client rates drawn once from a
               Beta(c·p_bc, c·(1−p_bc)) profile (mean ``p_bc``, heterogeneity
               controlled by the concentration ``c``); i.i.d. thinning per
               slot at each client's own rate.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

SCENARIOS = ("bernoulli", "markov", "diurnal", "hetero")


class HarvestProcess(NamedTuple):
    """A stateful energy-arrival process (see module docstring)."""

    name: str
    persistent: bool  # state survives across epochs (else re-seeded per epoch)
    mean_rate: float  # configured long-run arrival rate (units/slot/client)
    init: Callable[[jax.Array, int], Any]
    step: Callable[[Any, jax.Array], Tuple[jax.Array, Any]]


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


def _shard_slice(full: jax.Array, _shard, n_loc: int) -> jax.Array:
    """This shard's (N_loc,) window of a globally-shaped (N,) draw.
    ``_shard = (axis_name, n_global)`` under ``shard_map`` (DESIGN.md §9)."""
    axis_name, _ = _shard
    off = jax.lax.axis_index(axis_name) * n_loc
    return jax.lax.dynamic_slice(full, (off,), (n_loc,))


def bernoulli(p_bc: float, _shard=None) -> HarvestProcess:
    """Paper-faithful i.i.d. arrivals (Eq. 3).  State is just the PRNG key;
    the split/draw sequence is bit-identical to the original
    ``energy.harvest_step``."""

    def init(key: jax.Array, n: int) -> jax.Array:
        return key

    def step(key: jax.Array, battery: jax.Array):
        k1, k2 = jax.random.split(key)
        if _shard is None:
            charge = jax.random.bernoulli(k1, p_bc, battery.shape)
        else:
            full = jax.random.bernoulli(k1, p_bc, (_shard[1],))
            charge = _shard_slice(full, _shard, battery.shape[0])
        return charge.astype(jnp.int32), k2

    return HarvestProcess("bernoulli", False, float(p_bc), init, step)


def markov(p_bc: float, p_on: float = 0.8, sojourn: float = 8.0, _shard=None) -> HarvestProcess:
    """Gilbert–Elliott ON/OFF bursts.  Each client holds a binary phase z;
    arrivals occur w.p. ``p_on`` while ON and never while OFF.  The
    stationary ON-fraction pi = p_bc / p_on makes the long-run rate exactly
    ``p_bc``; ``sojourn`` = 1/(g2b + b2g) is the phase-relaxation timescale,
    so the mean ON sojourn is sojourn/(1-pi) and the mean OFF sojourn
    sojourn/pi (scarce energy = rare but long ON bursts separated by long
    blackouts — at the defaults p_bc=0.1, p_on=0.8: ~9-slot bursts, ~64-slot
    blackouts)."""
    # clamp into [p_bc, 1]: below p_bc the mean is unreachable, above 1 the
    # ON-state draw saturates and would silently undershoot the mean
    p_on = min(1.0, max(float(p_on), min(1.0, float(p_bc))))
    pi_on = 0.0 if p_on == 0.0 else min(1.0, float(p_bc) / p_on)
    sojourn = max(1.0, float(sojourn))
    g2b = (1.0 - pi_on) / sojourn  # ON -> OFF
    b2g = pi_on / sojourn  # OFF -> ON

    def init(key: jax.Array, n: int):
        k_z, k_run = jax.random.split(key)
        if _shard is None:
            z = jax.random.bernoulli(k_z, pi_on, (n,))
        else:
            z = _shard_slice(jax.random.bernoulli(k_z, pi_on, (_shard[1],)), _shard, n)
        return z, k_run

    def step(state, battery: jax.Array):
        z, key = state
        k_arr, k_flip, k_next = jax.random.split(key, 3)
        if _shard is None:
            charge = jax.random.bernoulli(
                k_arr, jnp.where(z, p_on, 0.0)
            ).astype(jnp.int32)
            flip = jax.random.bernoulli(k_flip, jnp.where(z, g2b, b2g))
        else:  # bernoulli(k, p) == uniform(k, p.shape, dtype(p)) < p, sliced
            n_loc = z.shape[0]
            u_arr = _shard_slice(jax.random.uniform(k_arr, (_shard[1],)), _shard, n_loc)
            u_flip = _shard_slice(jax.random.uniform(k_flip, (_shard[1],)), _shard, n_loc)
            charge = (u_arr < jnp.where(z, p_on, 0.0)).astype(jnp.int32)
            flip = u_flip < jnp.where(z, g2b, b2g)
        return charge, (z ^ flip, k_next)

    return HarvestProcess("markov", True, float(p_bc), init, step)


def diurnal(p_bc: float, period: float = 240.0, day_frac: float = 0.5, _shard=None) -> HarvestProcess:
    """Solar-like deterministic intensity × Bernoulli thinning.  One "day" is
    ``period`` slots; the first ``day_frac`` of it is daylight with half-sine
    intensity, the rest is night (zero arrivals).  The slot clock persists
    across epochs, so days span epochs.

    The waveform is renormalized so the day-averaged rate is exactly
    ``p_bc`` for ANY p_bc in [0, 1] (the gallery's mean-rate-matched
    guarantee): while p_bc <= 2*day_frac/pi the half-sine peak is scaled
    down; for larger p_bc the daylight window widens (peak pinned at 1)
    up to the full day; beyond p_bc = 2/pi — where even a full-day sine
    cannot carry the mean — a constant base rate fills the remainder
    (night disappears, as it must at near-saturated harvest)."""
    period = max(1.0, float(period))
    day_frac = min(1.0, max(1e-6, float(day_frac)))
    p_bc = min(1.0, max(0.0, float(p_bc)))
    full_sine_mean = 2.0 / math.pi
    if p_bc <= day_frac * full_sine_mean:
        p_peak, base = p_bc / (day_frac * full_sine_mean), 0.0
    elif p_bc <= full_sine_mean:
        day_frac, p_peak, base = p_bc / full_sine_mean, 1.0, 0.0
    else:  # base + (1-base) * full-day sine, solved for the exact mean
        day_frac, p_peak = 1.0, 1.0
        base = (p_bc - full_sine_mean) / (1.0 - full_sine_mean)

    def intensity(t: jax.Array) -> jax.Array:
        phase = (t.astype(jnp.float32) % period) / period  # [0, 1)
        day = phase < day_frac
        return jnp.where(day, jnp.sin(jnp.pi * phase / day_frac), 0.0)

    def init(key: jax.Array, n: int):
        return jnp.zeros((), jnp.int32), key

    def step(state, battery: jax.Array):
        t, key = state
        k1, k2 = jax.random.split(key)
        p_t = base + (1.0 - base) * p_peak * intensity(t)
        if _shard is None:
            charge = jax.random.bernoulli(k1, p_t, battery.shape)
        else:
            full = jax.random.uniform(k1, (_shard[1],)) < p_t
            charge = _shard_slice(full, _shard, battery.shape[0])
        return charge.astype(jnp.int32), (t + 1, k2)

    return HarvestProcess("diurnal", True, float(p_bc), init, step)


def hetero(p_bc: float, concentration: float = 2.0, _shard=None) -> HarvestProcess:
    """Static per-client rates r_i ~ Beta(c*p_bc, c*(1-p_bc)) — mean ``p_bc``,
    spread controlled by the concentration c (small c = a few energy-rich
    clients among many starved ones; the EH-IoT deployment profile)."""
    c = max(1e-3, float(concentration))
    degenerate = not (0.0 < p_bc < 1.0)

    def init(key: jax.Array, n: int):
        k_r, k_run = jax.random.split(key)
        n_draw = n if _shard is None else _shard[1]
        if degenerate:
            rates = jnp.full((n_draw,), float(p_bc), jnp.float32)
        else:
            rates = jax.random.beta(k_r, c * p_bc, c * (1.0 - p_bc), (n_draw,))
        if _shard is not None:
            rates = _shard_slice(rates, _shard, n)
        return rates.astype(jnp.float32), k_run

    def step(state, battery: jax.Array):
        rates, key = state
        k1, k2 = jax.random.split(key)
        if _shard is None:
            charge = jax.random.bernoulli(k1, rates)
        else:
            u = _shard_slice(jax.random.uniform(k1, (_shard[1],)), _shard, rates.shape[0])
            charge = u < rates
        return charge.astype(jnp.int32), (rates, k2)

    return HarvestProcess("hetero", True, float(p_bc), init, step)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FACTORIES: dict = {
    "bernoulli": bernoulli,
    "markov": markov,
    "diurnal": diurnal,
    "hetero": hetero,
}


def make_process(name: str, p_bc: float, **params: float) -> HarvestProcess:
    """Build a named scenario; ``p_bc`` is the target mean rate for all of
    them (the Bernoulli shorthand kept for backward compatibility)."""
    if name not in _FACTORIES:
        raise ValueError(f"unknown harvest scenario {name!r}; known: {SCENARIOS}")
    return _FACTORIES[name](p_bc, **params)


# ---------------------------------------------------------------------------
# Client-sharded variants (fleet path, DESIGN.md §9)
# ---------------------------------------------------------------------------


def state_sharding_tree(name: str):
    """Pytree matching the scenario's state structure: True where the leaf is
    per-client (leading N axis -> shard it over the client mesh axis), False
    where replicated (keys/clocks).  ``bernoulli`` state is just the key."""
    return {
        "bernoulli": False,
        "markov": (True, False),  # (z, key)
        "diurnal": (False, False),  # (clock, key)
        "hetero": (True, False),  # (rates, key)
    }[name]


def make_sharded_process(
    name: str, p_bc: float, *, axis_name: str, n_global: int, **params: float
) -> HarvestProcess:
    """Client-sharded counterpart of :func:`make_process` for the fleet path
    (DESIGN.md §9): ``init(key, n_loc)`` / ``step(state, battery_loc)``
    operate on this shard's (N_loc,) window under ``shard_map``, with the
    per-client state pieces (Markov phases, hetero rates) local to the shard
    and keys/clocks replicated — and every random draw BIT-IDENTICAL to the
    single-device process.  The recipe: draw with the *global* shape from the
    replicated key, then ``dynamic_slice`` this shard's window (for the
    probability-vector draws this uses jax's documented ``bernoulli(key, p)
    == uniform(key, p.shape, dtype(p)) < p``; asserted against the global
    processes in ``tests/test_fleet.py``)."""
    if name not in _FACTORIES:
        raise ValueError(f"unknown harvest scenario {name!r}; known: {SCENARIOS}")
    return _FACTORIES[name](p_bc, _shard=(axis_name, n_global), **params)
