"""The paper's client model (§V): 6 conv layers, 3 max-pools, 3 FC layers.

Pure-functional JAX; used by the EHFL simulator with *stacked* per-client
parameters (vmap over the client axis).  ``feature_vector`` taps the output
layer (10 logits -> softmax), exactly the paper's lightweight VAoI proxy
("representations from the output layer ... 10 elements").
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.cifar_cnn import CNNConfig
from repro.models.common import Params, softmax_cross_entropy


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / jnp.sqrt(kh * kw * cin)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale


def init_params(cfg: CNNConfig, key: jax.Array) -> Params:
    p: Params = {}
    cin = cfg.in_channels
    ks = jax.random.split(key, len(cfg.conv_channels) + len(cfg.fc_dims) + 1)
    for i, cout in enumerate(cfg.conv_channels):
        p[f"conv{i}_w"] = _conv_init(ks[i], 3, 3, cin, cout)
        p[f"conv{i}_b"] = jnp.zeros((cout,), jnp.float32)
        cin = cout
    spatial = cfg.image_size // 8  # three 2x2 max-pools
    d = spatial * spatial * cfg.conv_channels[-1]
    dims = (d,) + cfg.fc_dims + (cfg.num_classes,)
    for i in range(len(dims) - 1):
        k = ks[len(cfg.conv_channels) + i]
        p[f"fc{i}_w"] = jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32) / jnp.sqrt(dims[i])
        p[f"fc{i}_b"] = jnp.zeros((dims[i + 1],), jnp.float32)
    return p


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def forward(cfg: CNNConfig, p: Params, images: jax.Array) -> jax.Array:
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    x = images
    for i in range(len(cfg.conv_channels)):
        x = jax.lax.conv_general_dilated(
            x, p[f"conv{i}_w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) + p[f"conv{i}_b"]
        x = jax.nn.relu(x)
        if i % 2 == 1:  # pool after every second conv -> 3 pools
            x = _maxpool(x)
    x = x.reshape(x.shape[0], -1)
    n_fc = len(cfg.fc_dims) + 1
    for i in range(n_fc):
        x = x @ p[f"fc{i}_w"] + p[f"fc{i}_b"]
        if i < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(cfg: CNNConfig, p: Params, images: jax.Array, labels: jax.Array) -> jax.Array:
    return softmax_cross_entropy(forward(cfg, p, images), labels)


def feature_vector(cfg: CNNConfig, p: Params, images: jax.Array) -> jax.Array:
    """Paper's proxy feature: mean softmax output over the batch (Eq. 5/6)."""
    probs = jax.nn.softmax(forward(cfg, p, images).astype(jnp.float32), axis=-1)
    return jnp.mean(probs, axis=0)


def predictions(cfg: CNNConfig, p: Params, images: jax.Array) -> jax.Array:
    return jnp.argmax(forward(cfg, p, images), axis=-1)


def macro_f1(preds: jax.Array, labels: jax.Array, num_classes: int) -> jax.Array:
    """Macro-averaged F1 (the paper's learning metric)."""
    f1s = []
    for c in range(num_classes):
        tp = jnp.sum((preds == c) & (labels == c))
        fp = jnp.sum((preds == c) & (labels != c))
        fn = jnp.sum((preds != c) & (labels == c))
        f1s.append(2 * tp / jnp.maximum(2 * tp + fp + fn, 1))
    return jnp.mean(jnp.stack(f1s))
