"""Infrastructure tests: checkpointing, sharding rules, HLO analysis, optim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import load_pytree, save_pytree
from repro.launch import hlo_analysis, sharding
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw_init, adamw_update, sgd_update


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {
        "a": jax.random.normal(rng, (3, 4)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32), "c": (jnp.ones((2,), jnp.bfloat16),)},
    }
    path = tmp_path / "ckpt.npz"
    save_pytree(tree, path)
    restored = load_pytree(jax.tree.map(jnp.zeros_like, tree), path)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_model_params(tmp_path, rng):
    from repro.configs import get_config, reduced
    from repro.models import decoder

    cfg = reduced(get_config("jamba-v0.1-52b"))
    params = decoder.init_params(cfg, rng, max_seq=32)
    save_pytree(params, tmp_path / "m.npz")
    restored = load_pytree(params, tmp_path / "m.npz")
    toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    l1, _ = decoder.forward_logits(cfg, params, toks)
    l2, _ = decoder.forward_logits(cfg, restored, toks)
    np.testing.assert_array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class _FakeLeaf:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize(
    "path,shape,expected",
    [
        ("embed", (102400, 2048), P("model", "data")),
        ("blocks/0/attn/wq", (28, 2048, 2048), P(None, "data", "model")),
        ("blocks/0/attn/wo", (28, 2048, 2048), P(None, "model", "data")),
        ("blocks/0/moe/w_gate", (28, 64, 2048, 1408), P(None, "model", "data", None)),
        ("blocks/0/moe/shared/w_up", (28, 2048, 2816), P(None, "data", "model")),
        ("blocks/0/moe/router", (28, 2048, 64), P(None, None, None)),
        ("blocks/0/norm1/scale", (28, 2048), P(None, None)),
        ("blocks/0/ssm/in_proj", (48, 2048, 8512), P(None, "data", "model")),
        ("blocks/0/ssm/A_log", (48, 64), P(None, None)),
        ("final_norm/scale", (2048,), P(None)),
    ],
)
def test_param_pspec_rules(path, shape, expected):
    spec = sharding.param_pspec(path, _FakeLeaf(shape), _FakeMesh(), mode="fsdp")
    assert spec == expected


def test_param_pspec_tp_mode_drops_fsdp():
    spec = sharding.param_pspec("blocks/0/attn/wq", _FakeLeaf((28, 2048, 2048)), _FakeMesh(), "tp")
    assert spec == P(None, None, "model")


def test_param_pspec_indivisible_falls_back():
    # vocab 92553 is odd -> not divisible by 16 -> replicated on that dim
    spec = sharding.param_pspec("embed", _FakeLeaf((92553, 2048)), _FakeMesh())
    assert spec == P(None, "data")


def test_collective_bytes_parsing():
    hlo = """
  %ag = bf16[16,4096,2048]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[256,1024]{1,0} all-reduce(%y), to_apply=%add
  %a2a = bf16[8,128]{1,0} all-to-all(%z)
  %cp = f32[4]{0} collective-permute(%w)
  %rs = f32[16]{0} reduce-scatter(%v)
  %notacoll = f32[999]{0} add(%a, %b)
"""
    got = hlo_analysis.collective_bytes(hlo)
    assert got["all-gather"] == 16 * 4096 * 2048 * 2
    assert got["all-reduce"] == 256 * 1024 * 4
    assert got["all-to-all"] == 8 * 128 * 2
    assert got["collective-permute"] == 16
    assert got["reduce-scatter"] == 64
    assert got["total"] == sum(
        got[k] for k in ("all-gather", "all-reduce", "all-to-all", "collective-permute", "reduce-scatter")
    )


def test_roofline_terms_bottleneck():
    t = hlo_analysis.roofline_terms(1e12, 1e9, 1e6, 197e12, 819e9, 50e9)
    assert t["bottleneck"] == "compute"
    t = hlo_analysis.roofline_terms(1e9, 1e12, 1e6, 197e12, 819e9, 50e9)
    assert t["bottleneck"] == "memory"
    t = hlo_analysis.roofline_terms(1e9, 1e9, 1e12, 197e12, 819e9, 50e9)
    assert t["bottleneck"] == "collective"


def test_sgd_and_adamw_decrease_quadratic(rng):
    params = {"w": jax.random.normal(rng, (8,))}

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    g = jax.grad(loss)(params)
    p2 = sgd_update(params, g, 0.1)
    assert loss(p2) < loss(params)
    st = adamw_init(params)
    p3, st = adamw_update(params, g, st, 0.1, weight_decay=0.0)
    assert loss(p3) < loss(params)


def test_host_mesh_and_batch_spec():
    mesh = make_host_mesh()
    assert "data" in mesh.axis_names
    spec = sharding.batch_spec(mesh, batch=mesh.shape["data"] * 4, extra_dims=1)
    assert spec[0] in ("data", ("data",))  # P() normalizes 1-tuples
    # indivisible batch falls back to replication
    spec = sharding.batch_spec(mesh, batch=1, extra_dims=1) if mesh.shape["data"] > 1 else P(None, None)
    assert spec[0] in (None, ("data",))


def test_config_param_counts_sane():
    from repro.configs import get_config

    # within a factor-2 band of the published sizes
    approx = {
        "qwen1.5-0.5b": 0.62e9,  # incl. embeddings
        "command-r-35b": 35e9,
        "mamba2-1.3b": 1.3e9,
        "codeqwen1.5-7b": 7e9,
    }
    for name, target in approx.items():
        n = get_config(name).param_count()
        assert 0.5 * target < n < 2.2 * target, (name, n)
    # MoE: active far below total
    moe = get_config("llama4-scout-17b-a16e")
    assert moe.active_param_count() < 0.35 * moe.param_count()
