from repro.data.stream import (  # noqa: F401
    SCENARIOS as STREAM_SCENARIOS,
    DataStream,
    apply_view,
    make_sharded_stream,
    make_stream,
)
from repro.data.synthetic import (  # noqa: F401
    dirichlet_label_partition,
    make_federated_dataset,
    make_token_dataset,
)
