"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs in Python with real BlockSpec tiling semantics, which is how
we validate them against the ``ref.py`` oracles.  On TPU they compile to
Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.fedavg_reduce import fedavg_reduce as _fedavg_reduce
from repro.kernels.swa_attention import swa_attention as _swa_attention
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan
from repro.kernels.vaoi_distance import vaoi_distance as _vaoi_distance


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def vaoi_distance(v, h, age, q, mu, **kw):
    kw.setdefault("interpret", _interpret())
    return _vaoi_distance(v, h, age, q, mu, **kw)


def vaoi_update(age, m_unused, q, mu):
    """Deprecated shim kept for the simulator's kernel path; prefer
    vaoi_distance which fuses the distance."""
    raise NotImplementedError("use vaoi_distance(v, h, age, q, mu)")


def fedavg_reduce(msgs, weights, **kw):
    """Weighted (K, P) -> (P,) reduce.  K may be the full client axis N or
    the compacted ``cap``-sized training slab (DESIGN.md §11); the kernel
    pads small K up to the sublane multiple, so slab calls stay aligned."""
    kw.setdefault("interpret", _interpret())
    return _fedavg_reduce(msgs, weights, **kw)


def swa_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _swa_attention(q, k, v, **kw)


def ssd_scan(x, dt, A, Bm, Cm, **kw):
    kw.setdefault("interpret", _interpret())
    return _ssd_scan(x, dt, A, Bm, Cm, **kw)


__all__ = ["vaoi_distance", "fedavg_reduce", "swa_attention", "ssd_scan", "ref"]
