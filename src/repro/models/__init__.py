from repro.models import attention, cnn, common, decoder, moe, ssd  # noqa: F401
