"""Paper Fig. 6: network-wide energy consumption, normalized within each
p_bc group by the max across the 4 schemes.

Claims validated: (i) energy is driven by participation frequency, not by
alpha; (ii) VAoI consumes substantially less than greedy FedAvg at high
p_bc (paper: up to 37% reduction); (iii) FedBacys-Odd is lowest."""
from __future__ import annotations


from benchmarks.ehfl_grid import POLICIES, run_grid, run_scenarios


def run(quick: bool = True):
    cells, st = run_grid(quick)
    rows = []
    alphas = sorted({a for (_, a, _) in cells})
    pbcs = sorted({p for (_, _, p) in cells})
    a_ref = alphas[0]  # paper uses alpha=0.1 for fig 6
    for p_bc in pbcs:
        totals = {pol: cells[(pol, a_ref, p_bc)]["total_energy"] for pol in POLICIES}
        mx = max(totals.values()) or 1.0
        for pol, e in totals.items():
            rows.append(
                {
                    "name": f"fig6/{pol}/p{p_bc}",
                    "us_per_call": 0.0,
                    "derived": f"energy={e:.0f};normalized={e/mx:.3f}",
                }
            )
        if totals["fedavg"] > 0:
            red = 1.0 - totals["vaoi"] / totals["fedavg"]
            rows.append(
                {
                    "name": f"fig6/vaoi_vs_fedavg_reduction/p{p_bc}",
                    "us_per_call": 0.0,
                    "derived": f"reduction={red:.3f}",
                }
            )
    # alpha-invariance of energy (claim i): compare vaoi energy across alphas
    if len(alphas) > 1:
        for p_bc in pbcs:
            es = [cells[("vaoi", a, p_bc)]["total_energy"] for a in alphas]
            spread = (max(es) - min(es)) / (max(es) or 1.0)
            rows.append(
                {
                    "name": f"fig6/alpha_invariance/p{p_bc}",
                    "us_per_call": 0.0,
                    "derived": f"rel_spread={spread:.3f}",
                }
            )
    # beyond-paper: energy/F1 robustness of VAoI across harvest scenarios at
    # the same mean arrival rate (bernoulli / markov / diurnal / hetero)
    scen_cells, _ = run_scenarios(quick)
    rows.extend(scenario_rows(scen_cells, st["epochs"]))
    return rows


def scenario_rows(scen_cells: dict, epochs: int) -> list:
    bern = scen_cells["bernoulli"]["total_energy"]
    rows = []
    for scenario, rec in scen_cells.items():
        # bernoulli's self-ratio is 1 by definition (covers the 0/0 cell)
        vs = 1.0 if scenario == "bernoulli" else rec["total_energy"] / (bern or 1.0)
        rows.append(
            {
                "name": f"fig6/scenario/{scenario}",
                "us_per_call": rec["wall_s"] * 1e6 / max(epochs, 1),
                "derived": (
                    f"energy={rec['total_energy']:.0f};"
                    f"vs_bernoulli={vs:.3f};"
                    f"final_f1={rec['f1'][-1]:.4f}"
                ),
            }
        )
    return rows
