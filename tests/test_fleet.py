"""Client-sharded fleet simulator (core/fleet.py, DESIGN.md §9).

The correctness contract: a sharded run matches the single-device
``run_simulation`` for any N divisible by the shard count — integer slot
dynamics (batteries, uploads, starts) and VAoI ages EXACTLY, float
trajectories (f1, avg_m) to fp32 rounding (macro-F1 is an argmax metric, so
last-ulp parameter differences can flip individual test predictions).

On one device this still exercises the whole shard_map/psum/all-gather
machinery with a single shard; the CI multi-device leg reruns it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_fleet, run_simulation
from repro.core import harvest as harvest_lib
from repro.core import policies as policy_lib
from repro.core import vaoi as vaoi_lib
from repro.core.simulator import _masked_mean, _masked_mean_kernel
from repro.data import make_federated_dataset
from repro.fl import cnn_backend
from repro.launch.mesh import make_fleet_mesh

TINY_CNN = CNNConfig(
    name="tiny", image_size=16, conv_channels=(4, 4, 8, 8, 8, 8), fc_dims=(32, 16)
)


@pytest.fixture(scope="module")
def backend():
    return cnn_backend(TINY_CNN)


@pytest.fixture(scope="module")
def worlds():
    return {
        n: make_federated_dataset(
            jax.random.PRNGKey(0), num_clients=n, samples_per_client=40,
            alpha=0.5, test_size=100, image_size=16,
        )
        for n in (16, 64)
    }


def _cfg(n, **kw):
    base = dict(
        num_clients=n, epochs=4, slots_per_epoch=12, kappa=8, p_bc=0.6,
        k=3, mu=0.1, e_max=13, eval_every=4, probe_size=10,
    )
    base.update(kw)
    return EHFLConfig(**base)


def _assert_fleet_matches_solo(cfg, backend, data, use_kernel=False):
    solo = run_simulation(cfg, backend, data, use_kernel=use_kernel)
    fleet = run_fleet(cfg, backend, data, use_kernel=use_kernel)
    ms, mf = solo["metrics"], fleet["metrics"]
    for k in (
        "energy", "n_started", "n_uploaded", "n_delivered", "n_failed",
        "n_dropped", "avg_age", "f1_epochs",
    ):
        np.testing.assert_array_equal(np.asarray(ms[k]), np.asarray(mf[k]), err_msg=k)
    # the continuous quantities agree to fp32 rounding *amplified by
    # training*: psum vs full-axis summation order differs in the last ulp,
    # and kappa SGD steps per epoch grow that deterministically (measured
    # max drift ~3e-3 after 4 epochs across all policy/scenario combos)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-2
        ),
        solo["global_params"],
        fleet["global_params"],
    )
    np.testing.assert_allclose(np.asarray(ms["avg_m"]), np.asarray(mf["avg_m"]), atol=1e-3)
    # macro-F1 is discrete (argmax over a 100-point test set): last-ulp
    # parameter differences can flip individual predictions, so its
    # granularity — not fp32 — sets the tolerance
    np.testing.assert_allclose(np.asarray(ms["f1"]), np.asarray(mf["f1"]), atol=0.1)
    for f in ("age", "battery", "pending", "counter", "retries", "backoff"):
        np.testing.assert_array_equal(
            np.asarray(getattr(solo["carry"], f)),
            np.asarray(getattr(fleet["carry"], f)),
            err_msg=f"carry.{f}",
        )


# a latin square over (N, policy, harvest scenario, data stream, uplink
# channel): every policy, harvest scenario, stream scenario, and channel
# scenario runs end to end, both fleet sizes see a spread of each, without
# the full 5x4x4x4x2 cross
_CHANNEL_PARAMS = {
    "ideal": (),
    "erasure": (("p_loss", 0.4),),
    "aloha": (("num_channels", 2.0),),
    "fading": (("p_bad", 0.4), ("sojourn", 2.0)),
}


@pytest.mark.parametrize(
    "n,policy,scenario,stream,channel",
    [
        (16, "vaoi", "bernoulli", "static", "ideal"),
        (16, "fedbacys", "markov", "drift", "erasure"),
        (16, "fedbacys_odd", "diurnal", "arrival", "aloha"),
        (16, "vaoi_soft", "hetero", "shift", "fading"),
        (64, "vaoi", "markov", "arrival", "erasure"),
        (64, "fedbacys", "bernoulli", "shift", "aloha"),
        (64, "fedavg", "hetero", "drift", "fading"),
    ],
)
def test_fleet_matches_solo(n, policy, scenario, stream, channel, worlds, backend):
    cfg = _cfg(
        n, policy=policy, harvest=scenario, stream=stream,
        stream_params=(("period", 3.0),) if stream in ("drift", "shift") else (),
        channel=channel, channel_params=_CHANNEL_PARAMS[channel],
    )
    _assert_fleet_matches_solo(cfg, backend, worlds[n])


def test_fleet_kernel_path_matches_solo(worlds, backend):
    """use_kernel=True end to end: the Pallas vaoi_distance + fedavg_reduce
    kernels run per shard inside shard_map."""
    cfg = _cfg(16, policy="vaoi")
    _assert_fleet_matches_solo(cfg, backend, worlds[16], use_kernel=True)


def test_masked_mean_kernel_matches_reference(rng):
    """Satellite: the fedavg_reduce-backed aggregation equals _masked_mean
    on a ragged pytree, including the no-uploads fallback."""
    ks = jax.random.split(rng, 4)
    stacked = {
        "w": jax.random.normal(ks[0], (12, 5, 3)),
        "b": jax.random.normal(ks[1], (12, 7)),
    }
    fallback = {"w": jax.random.normal(ks[2], (5, 3)), "b": jax.random.normal(ks[3], (7,))}
    mask = jnp.arange(12) % 3 == 0
    ref = _masked_mean(stacked, mask, fallback)
    ker = _masked_mean_kernel(stacked, mask, fallback)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6), ref, ker
    )
    none = jnp.zeros((12,), bool)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(a, b),
        _masked_mean_kernel(stacked, none, fallback),
        fallback,
    )


@pytest.mark.parametrize("scenario", harvest_lib.SCENARIOS)
def test_sharded_harvest_matches_global(scenario):
    """make_sharded_process draws are bit-identical to the global process
    (the global-draw-and-slice recipe, incl. the bernoulli==uniform<p
    identity the probability-vector scenarios rely on)."""
    n, steps = 16, 6
    mesh = make_fleet_mesh(num_clients=n)
    solo = harvest_lib.make_process(scenario, p_bc=0.4)
    shp = harvest_lib.make_sharded_process(
        scenario, p_bc=0.4, axis_name="data", n_global=n
    )
    key = jax.random.PRNGKey(3)
    battery = jnp.zeros((n,), jnp.int32)

    def roll(process, bat):
        state = process.init(key, bat.shape[0])
        cs = []
        for _ in range(steps):
            c, state = process.step(state, bat)
            cs.append(c)
        return jnp.stack(cs)

    want = roll(solo, battery)
    got = jax.jit(
        shard_map(
            lambda b: roll(shp, b), mesh=mesh, in_specs=P("data"),
            out_specs=P(None, "data"), check_rep=False,
        )
    )(battery)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got), err_msg=scenario)


@pytest.mark.parametrize("k", [1, 3, 10, 16])
def test_distributed_topk_matches_global(k, rng):
    """Distributed Alg. 2 == single-device select_topk, including the k >
    shard-size regime and the all-zero cold start (pure-noise scores)."""
    n = 16
    mesh = make_fleet_mesh(num_clients=n)
    for age in (
        jax.random.randint(rng, (n,), 0, 5).astype(jnp.float32),
        jnp.zeros((n,), jnp.float32),
    ):
        key = jax.random.fold_in(rng, k)
        want = vaoi_lib.select_topk(age, k, key)
        got = jax.jit(
            shard_map(
                lambda a: vaoi_lib.select_topk_sharded(
                    a, k, key, axis_name="data", n_global=n
                ),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False,
            )
        )(age)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("policy", ["vaoi_soft", "fedbacys", "fedbacys_odd", "fedavg"])
def test_epoch_selection_sharded_matches_global(policy, rng):
    n, k = 16, 4
    mesh = make_fleet_mesh(num_clients=n)
    spec = policy_lib.make_policy(policy, num_clients=n, k=k, num_groups=3)
    age = jax.random.randint(rng, (n,), 0, 6).astype(jnp.float32)
    for t in (0, 1, 5):
        epoch = jnp.asarray(t)
        key = jax.random.fold_in(rng, t)
        want = policy_lib.epoch_selection(spec, age, epoch, k, key)
        got = jax.jit(
            shard_map(
                lambda a: policy_lib.epoch_selection_sharded(
                    spec, a, epoch, k, key, axis_name="data", n_global=n
                ),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False,
            )
        )(age)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got), err_msg=policy)


def test_num_groups_threads_through_config(worlds, backend):
    """Satellite: EHFLConfig.num_groups reaches make_policy — FedBacys with
    G=2 schedules N/2 clients per epoch vs N/4 at the k-derived default.
    (p_bc=1, kappa=4: batteries reach kappa well before the S-kappa start
    deadline, so every scheduled client trains.)"""
    base = _cfg(16, policy="fedbacys", p_bc=1.0, k=4, kappa=4, epochs=1, eval_every=1)
    assert base.num_groups == 0  # default: G = N // k = 4
    small_g = dataclasses.replace(base, num_groups=2)
    n_default = int(np.asarray(run_simulation(base, backend, worlds[16])["metrics"]["n_started"])[0])
    n_small = int(np.asarray(run_simulation(small_g, backend, worlds[16])["metrics"]["n_started"])[0])
    assert n_default == 4 and n_small == 8


def test_run_fleet_validates_mesh(worlds, backend):
    cfg = _cfg(16)
    with pytest.raises(ValueError):  # no "data" axis
        run_fleet(cfg, backend, worlds[16], mesh=jax.make_mesh((1,), ("model",)))
    n_dev = len(jax.devices())
    if n_dev > 1:  # indivisible fleet (only constructible multi-device)
        with pytest.raises(ValueError):
            run_fleet(
                dataclasses.replace(cfg, num_clients=n_dev + 1), backend, worlds[16],
                mesh=jax.make_mesh((n_dev,), ("data",)),
            )
