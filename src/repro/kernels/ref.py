"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vaoi_distance_ref(
    v: jax.Array, h: jax.Array, age: jax.Array, q: jax.Array, mu: float
) -> tuple[jax.Array, jax.Array]:
    """Fused Eq. (5) + Eq. (7): distances M_i and updated ages.

    v, h: (N, F) float; age: (N,) float32; q: (N,) float32 in {0,1}.
    Returns (m (N,), new_age (N,)).
    """
    diff = v.astype(jnp.float32) - h.astype(jnp.float32)
    m = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    inc = jnp.where(m >= mu, age + 1.0, age)
    return m, inc * (1.0 - q)


def fedavg_reduce_ref(msgs: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted aggregation: msgs (K, P), weights (K,) -> (P,) in fp32."""
    w = weights.astype(jnp.float32)
    return jnp.einsum("kp,k->p", msgs.astype(jnp.float32), w)


def swa_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, window: int = 0, causal: bool = True
) -> jax.Array:
    """Sliding-window attention oracle. q,k,v: (B, H, S, D). window=0 => full."""
    B, H, S, D = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(D))
    iq = jnp.arange(S)[:, None]
    jk = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= jk <= iq
    if window > 0:
        mask &= jk > iq - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", attn, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """SSD oracle: exact sequential recurrence (O(S) states, fp32).

    x (B,S,nh,hp); dt (B,S,nh); A (nh,); Bm, Cm (B,S,ds).
    Returns (y (B,S,nh,hp), final_state (B,nh,hp,ds)).
    """
    B_, S, nh, hp = x.shape
    ds = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, t):
        xt, dtt, bt, ct = t  # (B,nh,hp), (B,nh), (B,ds), (B,ds)
        decay = jnp.exp(dtt * A[None, :])  # (B,nh)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    init = jnp.zeros((B_, nh, hp, ds), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2), Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3), final
