"""Property tests for the harvest-scenario library (repro.core.harvest).

Plain seeded-loop properties (no hypothesis dependency): battery bounds and
energy causality through ``scan_epoch`` for every scenario, bit-identity of
the ``bernoulli`` process with the legacy ``harvest_step``, and empirical
arrival rates against the configured mean."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy as energy_lib
from repro.core import harvest as harvest_lib


def _slot_state(n, S, key):
    return energy_lib.init_slot_state(n, key, S=S)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bernoulli_bit_identical_to_harvest_step(seed):
    """The bernoulli HarvestProcess replays the legacy harvest_step chain
    bit-for-bit: same charges, same battery, same key sequence."""
    p_bc, e_max = 0.3, 25
    proc = harvest_lib.bernoulli(p_bc)
    key = jax.random.PRNGKey(seed)
    battery = jnp.array([0, 3, 12, 24, 25], jnp.int32)
    state = proc.init(key, battery.shape[0])
    for _ in range(50):
        charge, state = proc.step(state, battery)
        battery_ref, key = energy_lib.harvest_step(key, battery, p_bc, e_max)
        battery = jnp.minimum(battery + charge.astype(battery.dtype), e_max)
        assert (battery == battery_ref).all()
        assert (state == key).all()  # key chains stay in lockstep


@pytest.mark.parametrize("scenario", harvest_lib.SCENARIOS)
@pytest.mark.parametrize("seed", [0, 7])
def test_scan_epoch_invariants_per_scenario(scenario, seed):
    """§III-C invariants hold under every arrival process: battery within
    [0, e_max], strict causality, started clients paid >= kappa, idle paid 0."""
    n, S, kappa, e_max = 16, 30, 8, 13
    proc = harvest_lib.make_process(scenario, p_bc=0.4)
    key = jax.random.PRNGKey(seed)
    st0 = _slot_state(n, S, key)._replace(harvest=proc.init(key, n))
    out = energy_lib.scan_epoch(
        st0, S=S, kappa=kappa, e_max=e_max, process=proc,
        want_fn=lambda s, st: jnp.ones((n,), bool),
    )
    battery = np.asarray(out.battery)
    used = np.asarray(out.energy_used)
    started = np.asarray(out.started)
    assert np.all(battery >= 0) and np.all(battery <= e_max)
    # causality: arrivals are <= 1 unit/slot in every scenario, so total
    # consumption can never exceed S (battery = harvested - used >= 0)
    assert np.all(used <= S)
    assert np.all(used[started] >= kappa)
    idle = ~started & ~np.asarray(out.uploaded) & ~np.asarray(out.pending)
    assert np.all(used[idle] == 0)


@pytest.mark.parametrize("scenario", harvest_lib.SCENARIOS)
def test_charges_are_unit_quantized(scenario):
    """Eq. 3's unit-energy quantization is preserved by every scenario."""
    proc = harvest_lib.make_process(scenario, p_bc=0.5)
    state = proc.init(jax.random.PRNGKey(0), 32)
    battery = jnp.zeros((32,), jnp.int32)
    for _ in range(20):
        charge, state = proc.step(state, battery)
        c = np.asarray(charge)
        assert c.shape == (32,)
        assert np.isin(c, [0, 1]).all()


@pytest.mark.parametrize(
    "scenario,p_bc,tol",
    [
        ("bernoulli", 0.1, 0.02),
        ("bernoulli", 0.7, 0.02),
        ("markov", 0.1, 0.03),
        ("markov", 0.3, 0.03),
        # diurnal renormalizes peak/daylight/base so the mean is exact at any
        # rate (three regimes); measure over whole days
        ("diurnal", 0.15, 0.03),
        ("diurnal", 0.5, 0.03),   # widened-daylight regime
        ("diurnal", 0.8, 0.03),   # base-rate regime (no night)
        # hetero: client-mean of Beta(c*p, c*(1-p)) concentrates slowly; wide
        # tolerance + many clients
        ("hetero", 0.3, 0.06),
    ],
)
def test_empirical_rate_matches_configured_mean(scenario, p_bc, tol):
    n, steps = 256, 1920  # 1920 = 8 full diurnal days (period 240)
    proc = harvest_lib.make_process(scenario, p_bc=p_bc)
    battery = jnp.zeros((n,), jnp.int32)

    def body(state, _):
        charge, state = proc.step(state, battery)
        return state, charge

    _, charges = jax.lax.scan(body, proc.init(jax.random.PRNGKey(3), n), None, length=steps)
    rate = float(np.asarray(charges, np.float64).mean())
    assert abs(rate - p_bc) < tol, f"{scenario}: empirical {rate:.4f} vs configured {p_bc}"


def test_markov_is_bursty():
    """ON/OFF bursts: consecutive-slot arrival correlation far exceeds the
    (zero) correlation of the i.i.d. bernoulli process at the same mean."""

    def autocorr(proc, steps=3000, n=64):
        battery = jnp.zeros((n,), jnp.int32)

        def body(state, _):
            charge, state = proc.step(state, battery)
            return state, charge

        _, c = jax.lax.scan(body, proc.init(jax.random.PRNGKey(0), n), None, length=steps)
        c = np.asarray(c, np.float64)
        a, b = c[:-1].ravel(), c[1:].ravel()
        return float(np.corrcoef(a, b)[0, 1])

    rho_markov = autocorr(harvest_lib.markov(0.2, p_on=0.8, sojourn=8.0))
    rho_bern = autocorr(harvest_lib.bernoulli(0.2))
    assert rho_markov > rho_bern + 0.1


def test_diurnal_has_nights():
    """Night slots (phase >= day_frac) harvest exactly nothing."""
    proc = harvest_lib.diurnal(0.15, period=240.0, day_frac=0.5)
    battery = jnp.zeros((64,), jnp.int32)

    def body(state, _):
        t = state[0]
        charge, state = proc.step(state, battery)
        return state, (t, charge.sum())

    _, (ts, sums) = jax.lax.scan(
        body, proc.init(jax.random.PRNGKey(0), 64), None, length=480
    )
    ts, sums = np.asarray(ts), np.asarray(sums)
    night = (ts % 240) >= 120
    assert sums[night].sum() == 0
    assert sums[~night].sum() > 0


def test_hetero_rates_are_heterogeneous_but_fixed():
    proc = harvest_lib.hetero(0.3, concentration=2.0)
    state = proc.init(jax.random.PRNGKey(0), 128)
    rates0 = np.asarray(state[0])
    assert rates0.std() > 0.05  # genuinely spread out
    assert abs(rates0.mean() - 0.3) < 0.1
    battery = jnp.zeros((128,), jnp.int32)
    _, state = proc.step(state, battery)
    assert (np.asarray(state[0]) == rates0).all()  # rates are static


def test_make_process_rejects_unknown():
    with pytest.raises(ValueError):
        harvest_lib.make_process("solar_flare", p_bc=0.1)
