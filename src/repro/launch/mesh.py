"""Production mesh definitions (TPU v5e target).

Single pod:  (16, 16)    -> axes ("data", "model")   = 256 chips
Multi-pod:   (2, 16, 16) -> axes ("pod", "data", "model") = 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"))


def make_fleet_mesh(num_shards: int | None = None, *, num_clients: int | None = None):
    """1-D client-fleet mesh, axes ("data",) — what ``core/fleet.run_fleet``
    shards the N axis over (DESIGN.md §9).  Defaults to every visible device
    (use ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` to virtualize
    K CPU devices).  If ``num_clients`` is given, the shard count is clamped
    to its largest divisor so the fleet divides evenly."""
    n = num_shards or len(jax.devices())
    if num_clients is not None:
        n = min(n, num_clients)
        while num_clients % n:
            n -= 1
    return jax.make_mesh((n,), ("data",))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for this mesh ((pod, data) when multi-pod)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


# Hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
