#!/usr/bin/env python
"""Validate the machine-readable BENCH_*.json perf files and gate on fleet
throughput regressions (the CI ``bench-smoke`` job).

Checks:
  * schema — every ``BENCH_*.json`` at the repo root is an object with
    ``bench`` (str), ``devices`` (int > 0), ``backend`` (str), and a
    non-empty ``rows`` list of flat dicts; every numeric value is finite
    (NaN/inf reject) and every throughput/latency field
    (``clients_per_s``, ``epoch_s``) is strictly positive;
  * regression — the fresh ``BENCH_fleet.json`` is compared row-by-row
    (matched on ``(N, shards, policy)``) against a baseline (default: the
    committed ``git show HEAD:BENCH_fleet.json``); any ``clients_per_s``
    drop beyond ``--max-regress`` (default 30%) fails.  Rows whose topology
    has no baseline counterpart are skipped with a note, so local runs on
    odd device counts don't false-alarm.  Absolute throughput is
    machine-sensitive, so the gate only fires when the two files carry the
    same host fingerprint (``devices``/``backend``/``cpus``); on a
    different machine class it prints a loud note instead — commit the
    fresh file (the CI job uploads it as an artifact) to re-arm the gate
    for that runner class.

Exit code 0 = all good; 1 = any schema violation or regression.

  python tools/check_bench.py
  python tools/check_bench.py --baseline /tmp/bench_fleet_baseline.json
"""
from __future__ import annotations

import argparse
import json
import math
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
THROUGHPUT_KEYS = ("clients_per_s", "epoch_s")


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"  FAIL: {msg}")


def check_schema(path: Path, doc: object, errors: list) -> None:
    name = path.name
    if not isinstance(doc, dict):
        return _fail(errors, f"{name}: top level must be an object")
    for field, typ in (("bench", str), ("devices", int), ("backend", str), ("rows", list)):
        if not isinstance(doc.get(field), typ):
            _fail(errors, f"{name}: missing/invalid {field!r} (want {typ.__name__})")
    if isinstance(doc.get("devices"), int) and doc["devices"] <= 0:
        _fail(errors, f"{name}: devices must be > 0")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return _fail(errors, f"{name}: rows must be a non-empty list")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            _fail(errors, f"{name}: rows[{i}] is not an object")
            continue
        for k, v in row.items():
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                if not math.isfinite(v):
                    _fail(errors, f"{name}: rows[{i}].{k} is not finite ({v})")
                elif k in THROUGHPUT_KEYS and v <= 0:
                    _fail(errors, f"{name}: rows[{i}].{k} must be > 0 (got {v})")


def _row_key(row: dict) -> tuple:
    """Fleet rows are matched on topology + policy + compaction mode, so the
    compact rows are gated against their own baseline exactly like dense
    ones (a dense row never masks a compact regression or vice versa).
    A missing ``compact`` field (pre-compaction baselines) normalizes to
    False so old dense rows stay comparable to fresh dense rows."""
    return (
        row.get("N"),
        row.get("shards"),
        row.get("policy"),
        bool(row.get("compact", False)),
    )


def load_baseline(arg: str | None) -> dict | None:
    """Baseline BENCH_fleet.json: an explicit path, else the committed copy."""
    if arg:
        return json.loads(Path(arg).read_text())
    try:
        blob = subprocess.run(
            ["git", "show", "HEAD:BENCH_fleet.json"],
            cwd=REPO, capture_output=True, text=True, check=True,
        ).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, FileNotFoundError, json.JSONDecodeError) as e:
        print(f"  note: no committed BENCH_fleet.json baseline ({e}); "
              "skipping regression check")
        return None


def comparable_hosts(fresh: dict, baseline: dict) -> bool:
    """Throughput is only comparable across runs on the same machine class:
    identical device count, backend, and (when both files record it) CPU
    count.  Older baselines without ``cpus`` compare on devices/backend."""
    for field in ("devices", "backend", "cpus"):
        a, b = fresh.get(field), baseline.get(field)
        if a is not None and b is not None and a != b:
            print(f"  note: {field} differs from baseline ({a} vs {b}); host "
                  "classes are not comparable — SKIPPING the throughput gate. "
                  "If the runner class changed, commit the fresh "
                  "BENCH_fleet.json (CI uploads it as an artifact) to re-arm.")
            return False
    return True


def check_regression(fresh: dict, baseline: dict, max_regress: float, errors: list) -> None:
    if not comparable_hosts(fresh, baseline):
        return
    base_rows = {_row_key(r): r for r in baseline.get("rows", []) if isinstance(r, dict)}
    compared = 0
    for row in fresh.get("rows", []):
        key = _row_key(row)
        base = base_rows.get(key)
        if base is None:
            print(f"  note: no baseline row for N={key[0]} shards={key[1]} "
                  f"policy={key[2]} compact={key[3]}; skipping")
            continue
        now, ref = row.get("clients_per_s"), base.get("clients_per_s")
        if not isinstance(now, (int, float)) or not isinstance(ref, (int, float)) or ref <= 0:
            continue
        compared += 1
        drop = 1.0 - now / ref
        status = "REGRESSION" if drop > max_regress else "ok"
        print(f"  fleet N={key[0]} shards={key[1]} compact={key[3]}: {now:.1f} "
              f"vs baseline {ref:.1f} clients/s ({-drop:+.1%}) {status}")
        if drop > max_regress:
            _fail(errors, f"BENCH_fleet.json: N={key[0]} compact={key[3]} "
                          f"clients_per_s regressed {drop:.1%} "
                          f"(> {max_regress:.0%} allowed)")
    if compared == 0:
        print("  note: no comparable fleet rows (topology changed?); "
              "regression check vacuous")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=None,
                    help="baseline BENCH_fleet.json path (default: git HEAD copy)")
    ap.add_argument("--max-regress", type=float, default=0.30,
                    help="max tolerated fractional clients_per_s drop (default 0.30)")
    args = ap.parse_args()

    errors: list = []
    bench_files = sorted(REPO.glob("BENCH_*.json"))
    if not bench_files:
        print("FAIL: no BENCH_*.json files at the repo root")
        return 1
    for path in bench_files:
        print(f"checking {path.name}")
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            _fail(errors, f"{path.name}: invalid JSON ({e})")
            continue
        check_schema(path, doc, errors)
        if path.name == "BENCH_fleet.json" and isinstance(doc, dict):
            baseline = load_baseline(args.baseline)
            if baseline is not None:
                check_regression(doc, baseline, args.max_regress, errors)
    if errors:
        print(f"\nFAIL: {len(errors)} problem(s)")
        return 1
    print(f"\nOK: {len(bench_files)} bench file(s) valid, no throughput regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
