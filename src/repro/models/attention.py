"""GQA attention: full/sliding-window training+prefill, KV-cache decode,
rolling-window cache for long-context decode, and cross-attention (whisper).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, apply_rope, dense_init


def init_attn(key: jax.Array, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, nh, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, nh * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nh * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, xq: jax.Array, xkv: jax.Array):
    B = xq.shape[0]
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, xq.shape[1], nh, hd)
    k = k.reshape(B, xkv.shape[1], nkv, hd)
    v = v.reshape(B, xkv.shape[1], nkv, hd)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,nh,hd), k (B,Sk,nkv,hd) -> scores (B,nh,Sq,Sk) with GQA grouping."""
    B, Sq, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(B, Sq, nkv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    return s.reshape(B, nh, Sq, k.shape[1])


def _gqa_out(attn: jax.Array, v: jax.Array) -> jax.Array:
    """attn (B,nh,Sq,Sk), v (B,Sk,nkv,hd) -> (B,Sq,nh*hd)."""
    B, nh, Sq, Sk = attn.shape
    nkv, hd = v.shape[2], v.shape[3]
    g = nh // nkv
    a = attn.reshape(B, nkv, g, Sq, Sk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", a, v)
    return o.reshape(B, Sq, nh * hd)


# q-chunked attention kicks in above this sequence length: the (S, S) score
# matrix is never materialized; each scan step holds only (B, nh, CQ, S).
CHUNK_THRESHOLD = 1024
Q_CHUNK = 512


def _masked_softmax_attn(
    q: jax.Array, k: jax.Array, v: jax.Array, q_offset, causal: bool, window: int
) -> jax.Array:
    """q: (B,Cq,nh,hd); k,v: (B,Sk,nkv,hd). Rows are absolute position
    q_offset + arange(Cq). Returns (B, Cq, nh*hd)."""
    scores = _gqa_scores(q, k).astype(jnp.float32)
    Cq, Sk = scores.shape[-2], scores.shape[-1]
    if causal:
        iq = q_offset + jnp.arange(Cq)[:, None]
        jk = jnp.arange(Sk)[None, :]
        mask = jk <= iq
        if window > 0:
            mask &= jk > iq - window
        scores = jnp.where(mask[None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_out(attn, v)


def attn_forward(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    window: int = 0,
    encoder_out: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross).

    x: (B, S, d). positions: (S,) or (B, S). window>0 => sliding-window causal.
    encoder_out: if given, cross-attention (no causal mask, no rope on kv).
    """
    xkv = encoder_out if encoder_out is not None else x
    q, k, v = _project_qkv(cfg, p, x, xkv)
    if cfg.use_rope and encoder_out is None:
        pos_b = positions if positions.ndim == 2 else positions[None, :]
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
    S = q.shape[1]
    is_causal = causal and encoder_out is None
    if S > CHUNK_THRESHOLD and S % Q_CHUNK == 0:
        # scan over q chunks; never materialize the (S, S) score matrix
        B, _, nh, hd = q.shape
        nchunks = S // Q_CHUNK
        qc = q.reshape(B, nchunks, Q_CHUNK, nh, hd).transpose(1, 0, 2, 3, 4)

        def chunk_fn(i, qi):
            return _masked_softmax_attn(qi, k, v, i * Q_CHUNK, is_causal, window)

        oc = jax.lax.map(lambda args: chunk_fn(*args), (jnp.arange(nchunks), qc))
        out = oc.transpose(1, 0, 2, 3).reshape(B, S, nh * hd)
    else:
        out = _masked_softmax_attn(q, k, v, 0, is_causal, window)
    out = out @ p["wo"]
    if cfg.attn_out_bias:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


def cross_kv(cfg: ModelConfig, p: Params, encoder_out: jax.Array):
    """Project the encoder output to cross-attention K/V once (prefill); decode
    then reads the cache instead of re-projecting 1500 frames per token."""
    B, S = encoder_out.shape[:2]
    nkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = encoder_out @ p["wk"]
    v = encoder_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k.reshape(B, S, nkv, hd), v.reshape(B, S, nkv, hd)


def cross_decode_cached(cfg: ModelConfig, p: Params, x: jax.Array, ck: jax.Array, cv: jax.Array) -> jax.Array:
    """One-token cross-attention against cached K/V. x: (B,1,d)."""
    B = x.shape[0]
    nh, hd = cfg.num_heads, cfg.head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, 1, nh, hd)
    scores = _gqa_scores(q, ck).astype(jnp.float32)
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(attn, cv) @ p["wo"]
    if cfg.attn_out_bias:
        out = out + p["bo"]
    return out


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> Dict[str, jax.Array]:
    nkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, nkv, hd), dtype),
        "v": jnp.zeros((batch, length, nkv, hd), dtype),
    }


def attn_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    positions: jax.Array,
    rolling: bool = False,
    encoder_out: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, d); positions: (B,) absolute position of the
    new token. ``rolling=True`` treats the cache as a circular buffer of width
    W (sub-quadratic long-context decode); otherwise it is a linear cache of
    capacity >= positions+1.  Cross-attention (encoder_out given) reads a
    static encoder KV (computed here; cache unused for brevity of the API).
    """
    if encoder_out is not None:
        q, k, v = _project_qkv(cfg, p, x, encoder_out)
        scores = _gqa_scores(q, k).astype(jnp.float32)
        attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = _gqa_out(attn, v) @ p["wo"]
        if cfg.attn_out_bias:
            out = out + p["bo"]
        return out, cache

    q, k, v = _project_qkv(cfg, p, x, x)  # (B,1,*,hd)
    if cfg.use_rope:
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k = apply_rope(k, positions[:, None], cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = (positions % W) if rolling else jnp.minimum(positions, W - 1)

    def write(buf, new):
        onehot = jax.nn.one_hot(slot, W, dtype=buf.dtype)  # (B, W)
        return buf * (1 - onehot[:, :, None, None]) + new * onehot[:, :, None, None]

    ck = write(cache["k"], k)
    cv = write(cache["v"], v)
    scores = _gqa_scores(q, ck).astype(jnp.float32)  # (B, nh, 1, W)
    slots = jnp.arange(W)[None, :]  # (1, W)
    if rolling:
        # slot j holds absolute position p_j = pos - ((pos - j) mod W); valid if p_j >= 0
        pj = positions[:, None] - jnp.mod(positions[:, None] - slots, W)
        valid = pj >= 0
    else:
        valid = slots <= positions[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(attn, cv) @ p["wo"]
    if cfg.attn_out_bias:
        out = out + p["bo"]
    return out, {"k": ck, "v": cv}
