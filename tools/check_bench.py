#!/usr/bin/env python
"""Validate the machine-readable BENCH_*.json perf files and gate on fleet
and channel throughput regressions (the CI ``bench-smoke`` job).

Checks:
  * schema — every ``BENCH_*.json`` at the repo root is an object with
    ``bench`` (str), ``devices`` (int > 0), ``backend`` (str), and a
    non-empty ``rows`` list of flat dicts; every numeric value is finite
    (NaN/inf reject) and every throughput/latency field
    (``clients_per_s``, ``epoch_s``) is strictly positive;
  * channel semantics — in ``BENCH_channel.json`` every lossy row
    (scenario != ``ideal``) has ``delivery_rate`` in (0, 1] (a 0 means the
    channel silenced the fleet entirely — the grid's loss knobs are mis-
    sized), and every ``ideal`` row has ``delivery_rate`` == 1 with zero
    retries/drops; the ideal rows must also BIT-MATCH the static cells of
    ``BENCH_stream.json`` (same policy/N/epochs/compact: f1, avg_age_mean,
    avg_m_mean, n_uploaded identical — the ideal channel IS the pre-channel
    simulator, DESIGN.md §12);
  * regression — fresh ``BENCH_fleet.json``/``BENCH_channel.json`` are
    compared row-by-row (matched on topology/scenario + policy + compaction)
    against a baseline (default: the committed ``git show HEAD:`` copy); any
    ``clients_per_s`` drop beyond ``--max-regress`` (default 30%) fails.
    Rows with no baseline counterpart are skipped with a note, so local runs
    on odd device counts don't false-alarm.  Absolute throughput is
    machine-sensitive, so the gate only fires when the two files carry the
    same host fingerprint (``devices``/``backend``/``cpus``); on a
    different machine class it prints a loud note instead — commit the
    fresh file (the CI job uploads it as an artifact) to re-arm the gate
    for that runner class.

Exit code 0 = all good; 1 = any schema violation or regression.

  python tools/check_bench.py
  python tools/check_bench.py --baseline /tmp/bench_fleet_baseline.json
"""
from __future__ import annotations

import argparse
import json
import math
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
THROUGHPUT_KEYS = ("clients_per_s", "epoch_s")
# the fields an ideal channel row must reproduce bit-for-bit from the
# corresponding BENCH_stream static cell (both files round identically)
IDEAL_MATCH_KEYS = ("f1", "avg_age_mean", "avg_m_mean", "n_uploaded")


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"  FAIL: {msg}")


def check_schema(path: Path, doc: object, errors: list) -> None:
    name = path.name
    if not isinstance(doc, dict):
        return _fail(errors, f"{name}: top level must be an object")
    for field, typ in (("bench", str), ("devices", int), ("backend", str), ("rows", list)):
        if not isinstance(doc.get(field), typ):
            _fail(errors, f"{name}: missing/invalid {field!r} (want {typ.__name__})")
    if isinstance(doc.get("devices"), int) and doc["devices"] <= 0:
        _fail(errors, f"{name}: devices must be > 0")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return _fail(errors, f"{name}: rows must be a non-empty list")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            _fail(errors, f"{name}: rows[{i}] is not an object")
            continue
        for k, v in row.items():
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                if not math.isfinite(v):
                    _fail(errors, f"{name}: rows[{i}].{k} is not finite ({v})")
                elif k in THROUGHPUT_KEYS and v <= 0:
                    _fail(errors, f"{name}: rows[{i}].{k} must be > 0 (got {v})")


def _fleet_key(row: dict) -> tuple:
    """Fleet rows are matched on topology + policy + compaction mode, so the
    compact rows are gated against their own baseline exactly like dense
    ones (a dense row never masks a compact regression or vice versa).
    A missing ``compact`` field (pre-compaction baselines) normalizes to
    False so old dense rows stay comparable to fresh dense rows."""
    return (
        row.get("N"),
        row.get("shards"),
        row.get("policy"),
        bool(row.get("compact", False)),
    )


def _channel_key(row: dict) -> tuple:
    """Channel rows are matched on scenario + its knob settings + policy +
    compaction + N (an erasure p_loss=0.2 row never gates a p_loss=0.8 one)."""
    params = row.get("params")
    return (
        row.get("N"),
        row.get("scenario"),
        tuple(sorted(params.items())) if isinstance(params, dict) else None,
        row.get("policy"),
        bool(row.get("compact", False)),
    )


def load_baseline(arg: str | None, filename: str) -> dict | None:
    """Baseline BENCH file: an explicit path, else the committed copy."""
    if arg:
        return json.loads(Path(arg).read_text())
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{filename}"],
            cwd=REPO, capture_output=True, text=True, check=True,
        ).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, FileNotFoundError, json.JSONDecodeError) as e:
        print(f"  note: no committed {filename} baseline ({e}); "
              "skipping regression check")
        return None


def comparable_hosts(fresh: dict, baseline: dict, filename: str) -> bool:
    """Throughput is only comparable across runs on the same machine class:
    identical device count, backend, and (when both files record it) CPU
    count.  Older baselines without ``cpus`` compare on devices/backend."""
    for field in ("devices", "backend", "cpus"):
        a, b = fresh.get(field), baseline.get(field)
        if a is not None and b is not None and a != b:
            print(f"  note: {field} differs from baseline ({a} vs {b}); host "
                  "classes are not comparable — SKIPPING the throughput gate. "
                  f"If the runner class changed, commit the fresh "
                  f"{filename} (CI uploads it as an artifact) to re-arm.")
            return False
    return True


def check_regression(
    fresh: dict, baseline: dict, max_regress: float, errors: list,
    *, filename: str = "BENCH_fleet.json", key_fn=_fleet_key,
) -> None:
    if not comparable_hosts(fresh, baseline, filename):
        return
    base_rows = {key_fn(r): r for r in baseline.get("rows", []) if isinstance(r, dict)}
    compared = 0
    for row in fresh.get("rows", []):
        key = key_fn(row)
        base = base_rows.get(key)
        if base is None:
            print(f"  note: no baseline row for {key}; skipping")
            continue
        now, ref = row.get("clients_per_s"), base.get("clients_per_s")
        if not isinstance(now, (int, float)) or not isinstance(ref, (int, float)) or ref <= 0:
            continue
        compared += 1
        drop = 1.0 - now / ref
        status = "REGRESSION" if drop > max_regress else "ok"
        print(f"  {filename} {key}: {now:.1f} "
              f"vs baseline {ref:.1f} clients/s ({-drop:+.1%}) {status}")
        if drop > max_regress:
            _fail(errors, f"{filename}: {key} clients_per_s regressed "
                          f"{drop:.1%} (> {max_regress:.0%} allowed)")
    if compared == 0:
        print(f"  note: no comparable {filename} rows (grid changed?); "
              "regression check vacuous")


def check_channel_semantics(doc: dict, errors: list) -> None:
    """Delivery-rate sanity per row (see module docstring)."""
    for i, row in enumerate(doc.get("rows", [])):
        if not isinstance(row, dict):
            continue
        rate = row.get("delivery_rate")
        if not isinstance(rate, (int, float)):
            _fail(errors, f"BENCH_channel.json: rows[{i}] missing delivery_rate")
            continue
        if row.get("scenario") == "ideal":
            if rate != 1.0 or row.get("retries") or row.get("drops"):
                _fail(errors, f"BENCH_channel.json: rows[{i}] is ideal but "
                              f"lossy (rate={rate}, retries={row.get('retries')}, "
                              f"drops={row.get('drops')})")
        elif not 0.0 < rate <= 1.0:
            _fail(errors, f"BENCH_channel.json: rows[{i}] "
                          f"({row.get('scenario')}/{row.get('policy')}) "
                          f"delivery_rate must be in (0, 1]; got {rate}")


def check_ideal_bitmatch(channel_doc: dict, errors: list) -> None:
    """Every ideal channel row must reproduce the matching BENCH_stream
    static cell bit-for-bit — the ideal channel is the pre-channel simulator."""
    stream_path = REPO / "BENCH_stream.json"
    if not stream_path.exists():
        print("  note: no BENCH_stream.json; skipping ideal bit-match check")
        return
    try:
        stream_doc = json.loads(stream_path.read_text())
    except json.JSONDecodeError:
        return  # schema pass on the stream file reports this
    static = {
        (r.get("policy"), r.get("N"), r.get("epochs"), bool(r.get("compact", False))): r
        for r in stream_doc.get("rows", [])
        if isinstance(r, dict) and r.get("scenario") == "static"
    }
    matched = 0
    for i, row in enumerate(channel_doc.get("rows", [])):
        if not isinstance(row, dict) or row.get("scenario") != "ideal":
            continue
        key = (row.get("policy"), row.get("N"), row.get("epochs"),
               bool(row.get("compact", False)))
        ref = static.get(key)
        if ref is None:
            print(f"  note: no BENCH_stream static cell for {key}; skipping")
            continue
        matched += 1
        for k in IDEAL_MATCH_KEYS:
            if row.get(k) != ref.get(k):
                _fail(errors, f"BENCH_channel.json: ideal row {key} diverges "
                              f"from the BENCH_stream static cell on {k!r} "
                              f"({row.get(k)} != {ref.get(k)}) — the ideal "
                              "channel must be bit-identical to the "
                              "pre-channel simulator")
    if matched:
        print(f"  ideal bit-match: {matched} row(s) checked against "
              "BENCH_stream static cells")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=None,
                    help="baseline BENCH_fleet.json path (default: git HEAD copy)")
    ap.add_argument("--channel-baseline", default=None,
                    help="baseline BENCH_channel.json path (default: git HEAD copy)")
    ap.add_argument("--max-regress", type=float, default=0.30,
                    help="max tolerated fractional clients_per_s drop (default 0.30)")
    args = ap.parse_args()

    errors: list = []
    bench_files = sorted(REPO.glob("BENCH_*.json"))
    if not bench_files:
        print("FAIL: no BENCH_*.json files at the repo root")
        return 1
    for path in bench_files:
        print(f"checking {path.name}")
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            _fail(errors, f"{path.name}: invalid JSON ({e})")
            continue
        check_schema(path, doc, errors)
        if not isinstance(doc, dict):
            continue
        if path.name == "BENCH_fleet.json":
            baseline = load_baseline(args.baseline, "BENCH_fleet.json")
            if baseline is not None:
                check_regression(doc, baseline, args.max_regress, errors)
        elif path.name == "BENCH_channel.json":
            check_channel_semantics(doc, errors)
            check_ideal_bitmatch(doc, errors)
            baseline = load_baseline(args.channel_baseline, "BENCH_channel.json")
            if baseline is not None:
                check_regression(
                    doc, baseline, args.max_regress, errors,
                    filename="BENCH_channel.json", key_fn=_channel_key,
                )
    if errors:
        print(f"\nFAIL: {len(errors)} problem(s)")
        return 1
    print(f"\nOK: {len(bench_files)} bench file(s) valid, no throughput regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
