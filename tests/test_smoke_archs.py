"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family (2 layers, d_model<=512, <=4 experts) runs one forward AND
one train step on CPU; output shapes + finiteness asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.launch.steps import make_train_step
from repro.models import decoder

ARCHS = list(list_configs())


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.num_prefix_tokens:
        batch["prefix_embeddings"] = jax.random.normal(
            ks[2], (B, cfg.num_prefix_tokens, cfg.d_model), cfg.dtype
        )
    if cfg.is_encoder_decoder:
        batch["encoder_frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = reduced(get_config(arch))
    assert cfg.num_layers <= max(2, cfg.block_period)
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch, rng):
    cfg = reduced(get_config(arch))
    params = decoder.init_params(cfg, rng, max_seq=64)
    batch = _batch(cfg, rng)
    logits, aux = decoder.forward_logits(
        cfg,
        params,
        batch["tokens"],
        prefix_embeddings=batch.get("prefix_embeddings"),
        encoder_frames=batch.get("encoder_frames"),
    )
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = reduced(get_config(arch))
    params = decoder.init_params(cfg, rng, max_seq=64)
    batch = _batch(cfg, rng)
    step = make_train_step(cfg, lr=0.1, remat=False)
    loss0, params1 = jax.jit(step)(params, batch)
    loss1, _ = jax.jit(step)(params1, batch)
    assert jnp.isfinite(loss0) and jnp.isfinite(loss1)
    assert float(loss1) < float(loss0)  # one SGD step on the same batch improves it
    # params actually changed
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), params, params1)
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch, rng):
    cfg = reduced(get_config(arch))
    params = decoder.init_params(cfg, rng, max_seq=64)
    B = 2
    cache = decoder.init_cache(cfg, B, 32)
    enc_out = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        enc_out = decoder._encode(cfg, params, frames)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = decoder.decode_step(cfg, params, cache, tok, jnp.zeros((B,), jnp.int32), encoder_out=enc_out)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
