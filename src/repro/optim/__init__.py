from repro.optim.sgd import adamw_init, adamw_update, sgd_update  # noqa: F401
