"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle across
shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fedavg_reduce import fedavg_reduce
from repro.kernels.swa_attention import swa_attention
from repro.kernels.vaoi_distance import vaoi_distance


@pytest.mark.parametrize("n,f", [(10, 10), (100, 10), (128, 512), (257, 300), (33, 1025)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vaoi_distance_sweep(n, f, dtype, rng):
    ks = jax.random.split(rng, 4)
    v = jax.random.normal(ks[0], (n, f), dtype)
    h = jax.random.normal(ks[1], (n, f), dtype)
    age = jax.random.randint(ks[2], (n,), 0, 7).astype(jnp.float32)
    q = (jax.random.uniform(ks[3], (n,)) < 0.3).astype(jnp.float32)
    m1, a1 = vaoi_distance(v, h, age, q, 0.5, interpret=True)
    m2, a2 = ref.vaoi_distance_ref(v, h, age, q, 0.5)
    tol = 1e-5 if dtype == jnp.float32 else 0.2
    np.testing.assert_allclose(m1, m2, rtol=tol, atol=tol)
    np.testing.assert_allclose(a1, a2, rtol=tol, atol=tol)


@pytest.mark.parametrize("blocks", [(32, 128), (128, 512), (64, 64)])
def test_vaoi_distance_block_invariance(blocks, rng):
    bn, bf = blocks
    v = jax.random.normal(rng, (200, 700))
    h = jax.random.normal(jax.random.fold_in(rng, 1), (200, 700))
    age = jnp.ones((200,))
    q = jnp.zeros((200,))
    m1, a1 = vaoi_distance(v, h, age, q, 1.0, block_n=bn, block_f=bf, interpret=True)
    m2, a2 = ref.vaoi_distance_ref(v, h, age, q, 1.0)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a1, a2, rtol=1e-5)


@pytest.mark.parametrize(
    "n,f,bn,bf",
    [
        (100, 130, 32, 64),   # both axes pad (100->128, 130->192)
        (10, 700, 8, 512),    # N pads within one block column
        (33, 33, 32, 32),     # one ragged element on each axis
        (5, 1025, 128, 512),  # bn clamps to N; F pads
    ],
)
def test_vaoi_distance_padding_paths(n, f, bn, bf, rng):
    """Pad-and-slice: N/F not multiples of the block sizes.  Padded rows
    carry zero age/q and must not leak into the sliced outputs."""
    ks = jax.random.split(rng, 4)
    v = jax.random.normal(ks[0], (n, f))
    h = jax.random.normal(ks[1], (n, f))
    age = jax.random.randint(ks[2], (n,), 0, 9).astype(jnp.float32)
    q = (jax.random.uniform(ks[3], (n,)) < 0.4).astype(jnp.float32)
    m1, a1 = vaoi_distance(v, h, age, q, 0.7, block_n=bn, block_f=bf, interpret=True)
    m2, a2 = ref.vaoi_distance_ref(v, h, age, q, 0.7)
    assert m1.shape == (n,) and a1.shape == (n,)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "k,p,bk,bp",
    [
        (5, 77, 4, 32),      # both axes pad (5->8, 77->96)
        (13, 100, 8, 64),    # ragged reduction tail
        (3, 2049, 64, 2048), # bk clamps to K; P pads by one element
        (65, 5, 64, 8),      # one extra K block, tiny P
    ],
)
def test_fedavg_reduce_padding_paths(k, p, bk, bp, rng):
    """Pad-and-slice on the reduction grid: zero-padded weights must not
    contribute to the accumulator."""
    msgs = jax.random.normal(rng, (k, p))
    w = jax.random.uniform(jax.random.fold_in(rng, 1), (k,))
    o1 = fedavg_reduce(msgs, w, block_k=bk, block_p=bp, interpret=True)
    o2 = ref.fedavg_reduce_ref(msgs, w)
    assert o1.shape == (p,)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k,p", [(1, 128), (10, 1000), (100, 4096), (7, 333), (64, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_reduce_sweep(k, p, dtype, rng):
    msgs = jax.random.normal(rng, (k, p), dtype)
    w = jax.random.uniform(jax.random.fold_in(rng, 1), (k,))
    w = w / w.sum()
    o1 = fedavg_reduce(msgs, w, interpret=True)
    o2 = ref.fedavg_reduce_ref(msgs, w)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(o1, o2, rtol=tol, atol=tol)


@pytest.mark.parametrize(
    "b,h,s,d,window",
    [
        (1, 2, 128, 64, 0),
        (2, 2, 256, 64, 64),
        (1, 1, 200, 32, 48),  # padded S
        (1, 2, 512, 128, 128),
        (2, 1, 128, 64, 16),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_sweep(b, h, s, d, window, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, h, s, d), dtype)
    v = jax.random.normal(ks[2], (b, h, s, d), dtype)
    o1 = swa_attention(q, k, v, window=window, block_q=64, block_k=64, interpret=True)
    o2 = ref.swa_attention_ref(q, k, v, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), rtol=tol, atol=tol
    )


def test_swa_matches_model_attention(rng):
    """The kernel agrees with the model's sliding-window attention path."""
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("starcoder2-3b"))
    assert cfg.sliding_window > 0
    B, S = 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, cfg.num_heads, S, cfg.head_dim))
    k = jax.random.normal(ks[1], (B, cfg.num_heads, S, cfg.head_dim))
    v = jax.random.normal(ks[2], (B, cfg.num_heads, S, cfg.head_dim))
    o_kernel = swa_attention(q, k, v, window=cfg.sliding_window, block_q=32, block_k=32, interpret=True)
    o_ref = ref.swa_attention_ref(q, k, v, window=cfg.sliding_window)
    np.testing.assert_allclose(o_kernel, o_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "b,s,nh,hp,ds,chunk",
    [
        (1, 32, 2, 64, 16, 8),
        (2, 64, 4, 64, 128, 16),
        (1, 50, 2, 32, 16, 16),  # padded S
        (1, 128, 1, 64, 128, 64),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, s, nh, hp, ds, chunk, dtype, rng):
    from repro.kernels.ssd_scan import ssd_scan

    ks = jax.random.split(rng, 5)
    x = (jax.random.normal(ks[0], (b, s, nh, hp)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (b, s, ds)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (b, s, ds)) * 0.5).astype(dtype)
    y1, s1 = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y2, s2 = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = 2e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(y1, y2, rtol=tol, atol=tol)
    np.testing.assert_allclose(s1, s2, rtol=tol, atol=tol)


def test_ssd_scan_matches_model_chunked(rng):
    """Kernel == the model's pure-jnp chunked SSD (the dry-run path)."""
    from repro.kernels.ssd_scan import ssd_scan
    from repro.models import ssd as ssd_lib

    ks = jax.random.split(rng, 5)
    b, s, nh, hp, ds = 2, 48, 4, 32, 16
    x = jax.random.normal(ks[0], (b, s, nh, hp)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, ds)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, ds)) * 0.5
    y1, s1 = ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    y2, s2 = ssd_lib.ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(y1, y2, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(s1, s2, rtol=2e-5, atol=2e-5)
