"""Alg. 1 — the full EHFL loop, as a single jitted program.

TPU-native formulation (see DESIGN.md §3): all per-client state is stacked on
a leading N axis (batteries, ages, pending flags, feature moments, *and model
parameters*); epochs are a ``lax.scan``; the slot-level energy dynamics are an
inner scan of cheap integer ops (``repro.core.energy``); local training is a
vmapped ``kappa``-step SGD scan.  The client axis is what shards over the
``data`` mesh axis at scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import energy as energy_lib
from repro.core import policies as policy_lib
from repro.core import vaoi as vaoi_lib
from repro.optim import sgd_update


@dataclass(frozen=True)
class EHFLConfig:
    num_clients: int = 100
    epochs: int = 500
    slots_per_epoch: int = 30  # S
    kappa: int = 20  # training cost in slots == battery units
    p_bc: float = 0.1  # Bernoulli harvest probability
    k: int = 10  # selection budget (Alg. 2)
    mu: float = 0.5  # VAoI significance threshold
    lr: float = 0.01  # SGD gamma
    probe_size: int = 30  # |B_i| for the proxy forward pass
    e_max: int = 25  # kappa + 5
    policy: str = "vaoi"
    alpha: float = 0.1  # Dirichlet concentration (data partition)
    seed: int = 0
    eval_every: int = 10
    aux_note: str = ""


class Backend(NamedTuple):
    """Model plug-in for the simulator (CNN for the paper; LMs at scale)."""

    init: Callable[[jax.Array], Any]
    grad_loss: Callable[[Any, jax.Array, jax.Array], Tuple[jax.Array, Any]]
    feature: Callable[[Any, jax.Array], jax.Array]  # (params, inputs) -> (F,)
    predict: Callable[[Any, jax.Array], jax.Array]
    feature_dim: int
    num_classes: int


class EpochCarry(NamedTuple):
    global_params: Any
    msg_params: Any  # (N, ...) stacked messages
    h: jax.Array  # (N, F) historical moments
    age: jax.Array  # (N,)
    battery: jax.Array  # (N,)
    pending: jax.Array  # (N,) bool
    counter: jax.Array  # (N,)
    key: jax.Array


def _local_train(
    params: Any,
    images: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    cfg: EHFLConfig,
    backend: Backend,
) -> Tuple[Any, jax.Array]:
    """BATCHTRAIN (Alg. 1 lines 23-29): kappa minibatch SGD steps over one
    permutation pass; accumulates Eq. (6) historical moment."""
    n = images.shape[0]
    bs = max(1, n // cfg.kappa)
    perm = jax.random.permutation(key, n)[: cfg.kappa * bs].reshape(cfg.kappa, bs)

    def step(carry, idx):
        params, fsum = carry
        imgs, lbls = images[idx], labels[idx]
        _, grads = backend.grad_loss(params, imgs, lbls)
        params = sgd_update(params, grads, cfg.lr)
        f = backend.feature(params, imgs)  # batch-mean feature of w^(t,b+1)
        return (params, fsum + f * bs), None

    (params, fsum), _ = jax.lax.scan(step, (params, jnp.zeros((backend.feature_dim,), jnp.float32)), perm)
    return params, fsum / (cfg.kappa * bs)


def _masked_mean(stacked: Any, mask: jax.Array, fallback: Any) -> Any:
    """FedAvg over the masked clients; fallback when no uploads."""
    cnt = jnp.sum(mask.astype(jnp.float32))

    def agg(leaf, fb):
        m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        s = jnp.sum(leaf * m, axis=0) / jnp.maximum(cnt, 1.0).astype(leaf.dtype)
        return jnp.where(cnt > 0, s, fb)

    return jax.tree.map(agg, stacked, fallback)


def run_simulation(
    cfg: EHFLConfig,
    backend: Backend,
    data: Dict[str, jax.Array],
    use_kernel: bool = False,
) -> Dict[str, Any]:
    """Run T epochs of Alg. 1. Returns metric trajectories + final model."""
    N, S, kappa = cfg.num_clients, cfg.slots_per_epoch, cfg.kappa
    spec = policy_lib.make_policy(cfg.policy, num_clients=N, k=cfg.k)
    key = jax.random.PRNGKey(cfg.seed)
    k_init, k_run = jax.random.split(key)

    global_params = backend.init(k_init)
    msg_params = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (N,) + x.shape), global_params)
    probe_imgs = data["images"][:, : cfg.probe_size]

    carry0 = EpochCarry(
        global_params=global_params,
        msg_params=msg_params,
        h=jnp.zeros((N, backend.feature_dim), jnp.float32),
        age=jnp.zeros((N,), jnp.float32),
        battery=jnp.zeros((N,), jnp.int32),
        pending=jnp.zeros((N,), bool),
        counter=jnp.zeros((N,), jnp.int32),
        key=k_run,
    )

    def epoch_body(carry: EpochCarry, t: jax.Array):
        k_sel, k_scan, k_train, k_next = jax.random.split(carry.key, 4)

        # --- CLIENTSELECT (Alg. 2) on the freshly-broadcast global model ---
        if spec.uses_vaoi:
            v = jax.vmap(lambda imgs: backend.feature(carry.global_params, imgs))(probe_imgs)
            selected = policy_lib.epoch_selection(spec, carry.age, t, cfg.k, k_sel)
            if use_kernel:  # fused Pallas kernel (Eq. 5 + Eq. 7 in one pass)
                from repro.kernels import ops as kops

                m, age = kops.vaoi_distance(
                    v, carry.h, carry.age, selected.astype(jnp.float32), cfg.mu
                )
            else:
                m = vaoi_lib.feature_distance(v, carry.h)
                age = vaoi_lib.vaoi_update(carry.age, m, selected.astype(jnp.float32), cfg.mu)
        else:
            selected = policy_lib.epoch_selection(spec, carry.age, t, cfg.k, k_sel)
            age = carry.age
            m = jnp.zeros((N,), jnp.float32)

        # --- slot-level energy dynamics ---
        want_fn = policy_lib.make_want_fn(spec, selected, S, kappa)
        opp_fn = policy_lib.make_opportunity_fn(spec, selected, S, kappa)
        st0 = energy_lib.SlotState(
            battery=carry.battery,
            started=jnp.zeros((N,), bool),
            start_slot=jnp.full((N,), S, jnp.int32),
            pending=carry.pending,
            uploaded=jnp.zeros((N,), bool),
            counter=carry.counter,
            energy_used=jnp.zeros((N,), jnp.int32),
            key=k_scan,
        )
        st = energy_lib.scan_epoch(
            st0, S=S, kappa=kappa, p_bc=cfg.p_bc, e_max=cfg.e_max,
            want_fn=want_fn, count_opportunity_fn=opp_fn,
        )

        # --- local training (vmapped; masked by st.started) ---
        pending_in = carry.pending  # entered the epoch with an unsent (old) message?
        train_keys = jax.random.split(k_train, N)
        trained, h_new = jax.vmap(
            lambda imgs, lbls, k: _local_train(carry.global_params, imgs, lbls, k, cfg, backend)
        )(data["images"], data["labels"], train_keys)
        started_m = st.started
        sel = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(started_m.reshape((-1,) + (1,) * (a.ndim - 1)), a, b), new, old
        )
        msg_params = sel(trained, carry.msg_params)
        h = jnp.where(started_m[:, None], h_new, carry.h)

        # --- aggregation (uploads of this epoch; old-pending uploads use old msgs) ---
        contrib = jax.tree.map(
            lambda old, new: jnp.where(
                pending_in.reshape((-1,) + (1,) * (old.ndim - 1)), old, new
            ),
            carry.msg_params,
            msg_params,
        )
        new_global = _masked_mean(contrib, st.uploaded, carry.global_params)

        metrics = {
            "energy": jnp.sum(st.energy_used),
            "avg_age": jnp.mean(age),
            "n_started": jnp.sum(st.started.astype(jnp.int32)),
            "n_uploaded": jnp.sum(st.uploaded.astype(jnp.int32)),
            "avg_m": jnp.mean(m),
        }
        return (
            EpochCarry(
                global_params=new_global,
                msg_params=msg_params,
                h=h,
                age=age,
                battery=st.battery,
                pending=st.pending,
                counter=st.counter,
                key=k_next,
            ),
            metrics,
        )

    scan_chunk = jax.jit(lambda c, ts: jax.lax.scan(epoch_body, c, ts))

    carry = carry0
    all_metrics = []
    f1s, f1_epochs = [], []
    eval_fn = jax.jit(lambda p, x: backend.predict(p, x))
    from repro.models.cnn import macro_f1

    chunk = max(1, cfg.eval_every)
    t = 0
    while t < cfg.epochs:
        n = min(chunk, cfg.epochs - t)
        carry, ms = scan_chunk(carry, jnp.arange(t, t + n))
        all_metrics.append(ms)
        preds = eval_fn(carry.global_params, data["test_images"])
        f1s.append(float(macro_f1(preds, data["test_labels"], backend.num_classes)))
        f1_epochs.append(t + n)
        t += n

    metrics = {k: jnp.concatenate([m[k] for m in all_metrics]) for k in all_metrics[0]}
    metrics["f1"] = jnp.array(f1s)
    metrics["f1_epochs"] = jnp.array(f1_epochs)
    metrics["total_energy"] = jnp.sum(metrics["energy"])
    return {"metrics": metrics, "global_params": carry.global_params, "carry": carry}
