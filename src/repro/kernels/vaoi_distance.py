"""Pallas TPU kernel: fused fleet-wide VAoI proxy evaluation.

Computes, for every client i in one HBM pass:
    M_i      = || v_i - h_i ||_2                      (Eq. 5)
    age_i'   = (age_i + [M_i >= mu]) * (1 - q_i)      (Eq. 7)

Tiling: grid (N/BN, F/BF).  The feature axis is reduced across the inner grid
dimension with a VMEM scratch accumulator; v/h tiles of (BN, BF) stream
through VMEM while the (BN,) age/q tiles stay resident.  Fusing distance +
threshold + age update avoids materializing the (N, F) diff and the (N,)
distance vector in HBM — at fleet scale (N ~ 1e5 clients, F = vocab-sized
features) the diff alone would be tens of GB of traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(mu: float):
    def _kernel(v_ref, h_ref, age_ref, q_ref, m_ref, age_out_ref, acc_ref):
        j = pl.program_id(1)
        nf = pl.num_programs(1)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        diff = v_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.sum(diff * diff, axis=1)

        @pl.when(j == nf - 1)
        def _finalize():
            m = jnp.sqrt(acc_ref[...])
            age = age_ref[...].astype(jnp.float32)
            q = q_ref[...].astype(jnp.float32)
            inc = jnp.where(m >= mu, age + 1.0, age)
            m_ref[...] = m
            age_out_ref[...] = inc * (1.0 - q)

    return _kernel


@functools.partial(jax.jit, static_argnames=("mu", "block_n", "block_f", "interpret"))
def vaoi_distance(
    v: jax.Array,
    h: jax.Array,
    age: jax.Array,
    q: jax.Array,
    mu: float,
    *,
    block_n: int = 128,
    block_f: int = 512,
    interpret: bool = False,
):
    """v, h: (N, F); age, q: (N,). Returns (m (N,), new_age (N,)) fp32."""
    N, F = v.shape
    bn, bf = min(block_n, N), min(block_f, F)
    pad_n, pad_f = (-N) % bn, (-F) % bf
    if pad_n or pad_f:
        v = jnp.pad(v, ((0, pad_n), (0, pad_f)))
        h = jnp.pad(h, ((0, pad_n), (0, pad_f)))
        age = jnp.pad(age, (0, pad_n))
        q = jnp.pad(q, (0, pad_n))
    Np, Fp = N + pad_n, F + pad_f

    grid = (Np // bn, Fp // bf)
    m, new_age = pl.pallas_call(
        _make_kernel(float(mu)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32)],
        interpret=interpret,
    )(v, h, age, q)
    return m[:N], new_age[:N]
