"""Kernel micro-benchmarks: jnp reference implementations timed on CPU
(wall numbers are CPU-only; the Pallas kernels are TPU artifacts validated
in interpret mode — see tests/test_kernels.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []
    n, f = (1024, 4096) if quick else (8192, 16384)
    v = jax.random.normal(key, (n, f))
    h = jax.random.normal(key, (n, f))
    age = jnp.ones((n,))
    q = jnp.zeros((n,))
    us = _time(jax.jit(lambda *a: ref.vaoi_distance_ref(*a, 0.5)), v, h, age, q)
    rows.append({"name": f"kernel/vaoi_distance_ref/N{n}xF{f}", "us_per_call": us,
                 "derived": f"bytes={2*n*f*4};GBps={2*n*f*4/us/1e3:.2f}"})
    k, p = (64, 1 << 20) if quick else (128, 1 << 24)
    msgs = jax.random.normal(key, (k, p))
    w = jnp.ones((k,)) / k
    us = _time(jax.jit(ref.fedavg_reduce_ref), msgs, w)
    rows.append({"name": f"kernel/fedavg_reduce_ref/K{k}xP{p}", "us_per_call": us,
                 "derived": f"GBps={k*p*4/us/1e3:.2f}"})
    # slab-shaped reduce: the active-set compaction path (DESIGN.md §11)
    # aggregates a (cap, P) training slab instead of the (N, P) fleet —
    # cap=10 is the paper's k
    cap = 10
    slab = jax.random.normal(key, (cap, p))
    ws = jnp.ones((cap,)) / cap
    us = _time(jax.jit(ref.fedavg_reduce_ref), slab, ws)
    rows.append({"name": f"kernel/fedavg_reduce_ref/slab_K{cap}xP{p}", "us_per_call": us,
                 "derived": f"GBps={cap*p*4/us/1e3:.2f}"})
    b, hh, s, d = (1, 4, 1024, 64) if quick else (2, 8, 4096, 128)
    qq = jax.random.normal(key, (b, hh, s, d))
    us = _time(jax.jit(lambda q_, k_, v_: ref.swa_attention_ref(q_, k_, v_, window=256)), qq, qq, qq)
    flops = 4 * b * hh * s * 256 * d
    rows.append({"name": f"kernel/swa_attention_ref/S{s}w256", "us_per_call": us,
                 "derived": f"GFLOPs={flops/us/1e3:.2f}"})
    return rows
