"""InternVL2-2B [arXiv:2404.16821] — InternViT (stubbed frontend) + InternLM2 backbone."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        num_prefix_tokens=256,  # ViT patch embeddings after pixel-unshuffle+projector (stub)
        rope_theta=1_000_000.0,
        dtype=jnp.bfloat16,
        source="arXiv:2404.16821",
    )
)
