"""Batched serving demo: prefill + KV-cache decode on an assigned arch.

  PYTHONPATH=src python examples/serve_demo.py --arch starcoder2-3b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import decoder


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = decoder.init_params(cfg, key, max_seq=256)
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    cache_len = P + args.tokens
    cache = decoder.init_cache(cfg, B, cache_len)

    step = jax.jit(
        lambda params, cache, tok, pos: decoder.decode_step(cfg, params, cache, tok, pos)
    )

    # prefill by stepping the prompt through the cache (decode-based prefill)
    t0 = time.time()
    for t in range(P):
        logits, cache = step(params, cache, prompts[:, t : t + 1], jnp.full((B,), t))
    jax.block_until_ready(logits)
    print(f"prefill({P} tokens): {time.time()-t0:.2f}s (includes jit)")

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [tok]
    t0 = time.time()
    for t in range(P, P + args.tokens - 1):
        logits, cache = step(params, cache, tok, jnp.full((B,), t))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {args.tokens-1} tokens x batch {B} in {dt:.2f}s "
          f"({B*(args.tokens-1)/max(dt,1e-9):.1f} tok/s on CPU, reduced config)")
    for b in range(B):
        print(f"  seq[{b}]: {out[b].tolist()}")


if __name__ == "__main__":
    main()
