"""Alg. 1 — the full EHFL loop, as a single jitted program.

TPU-native formulation (see DESIGN.md §3): all per-client state is stacked on
a leading N axis (batteries, ages, pending flags, feature moments, *and model
parameters*); epochs are a ``lax.scan``; the slot-level energy dynamics are an
inner scan of cheap integer ops (``repro.core.energy``); local training is a
vmapped ``kappa``-step SGD scan over the *active set only* — the started
clients are gathered into a static ``PolicySpec.max_active``-sized slab, so
per-epoch training FLOPs scale with the participating set, not the
population (active-set compaction, DESIGN.md §11; ``compact=False`` forces
the dense all-N path).  The client axis is what shards over the
``data`` mesh axis at scale — ``repro.core.fleet.run_fleet`` runs this same
epoch body client-sharded under ``shard_map`` (DESIGN.md §9).

The epoch body is exposed as a pure ``(carry, t) -> (carry, metrics)``
function via :func:`make_epoch_fn`, which is what makes :func:`run_batch`
possible: the whole epoch scan (eval included) ``vmap``s over a seed axis and
runs a full multi-seed sweep cell as ONE jitted call (DESIGN.md §8).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import channel as channel_lib
from repro.core import energy as energy_lib
from repro.core import harvest as harvest_lib
from repro.core import policies as policy_lib
from repro.core import vaoi as vaoi_lib
from repro.data import stream as stream_lib
from repro.optim import sgd_update


@dataclass(frozen=True)
class EHFLConfig:
    num_clients: int = 100
    epochs: int = 500
    slots_per_epoch: int = 30  # S
    kappa: int = 20  # training cost in slots == battery units
    p_bc: float = 0.1  # mean harvest rate (Bernoulli probability, Eq. 3)
    k: int = 10  # selection budget (Alg. 2)
    mu: float = 0.5  # VAoI significance threshold
    lr: float = 0.01  # SGD gamma
    probe_size: int = 30  # |B_i| for the proxy forward pass
    e_max: int = 25  # kappa + 5
    policy: str = "vaoi"
    num_groups: int = 0  # FedBacys group count G (0 = default N // k)
    alpha: float = 0.1  # Dirichlet concentration (data partition)
    seed: int = 0
    eval_every: int = 10
    aux_note: str = ""
    # harvest scenario (repro.core.harvest; "bernoulli" keeps p_bc semantics
    # and reproduces seed behavior exactly).  harvest_params is a tuple of
    # (name, value) pairs so the config stays frozen/hashable.
    harvest: str = "bernoulli"
    harvest_params: Tuple[Tuple[str, float], ...] = ()
    # streaming-data scenario (repro.data.stream; "static" is the frozen
    # Dirichlet partition and reproduces seed behavior exactly).  Same
    # (name, value) tuple convention as harvest_params.
    stream: str = "static"
    stream_params: Tuple[Tuple[str, float], ...] = ()
    # uplink channel scenario (repro.core.channel; "ideal" is the lossless
    # pre-channel behavior and reproduces it exactly).  Same (name, value)
    # tuple convention as harvest_params/stream_params.
    channel: str = "ideal"
    channel_params: Tuple[Tuple[str, float], ...] = ()
    # retry state machine for failed uploads (DESIGN.md §12): a failed
    # carrier re-queues with capped exponential backoff (skip
    # min(2^(attempts-1), backoff_cap) epochs before re-contending) and is
    # dropped outright after max_retries failures — the spent energy is
    # never refunded.
    max_retries: int = 3
    backoff_cap: int = 8
    # active-set compaction (DESIGN.md §11): train only the clients that
    # actually started this epoch, gathered into a static-size slab of
    # ``PolicySpec.max_active`` lanes.  "auto" (the default) compacts
    # whenever the policy's slab is smaller than N (fedavg therefore always
    # falls back to the dense path); False forces the dense path.
    compact: Any = "auto"  # bool | "auto"

    def harvest_process(self) -> harvest_lib.HarvestProcess:
        return harvest_lib.make_process(
            self.harvest, p_bc=self.p_bc, **dict(self.harvest_params)
        )

    def data_stream(self, num_classes: int | None = None) -> stream_lib.DataStream:
        """``num_classes`` is the dataset's class count (the simulator passes
        ``backend.num_classes``); an explicit ``stream_params`` entry wins."""
        params = dict(self.stream_params)
        if num_classes is not None and self.stream in stream_lib.CLASS_CONDITIONED:
            params.setdefault("num_classes", num_classes)
        return stream_lib.make_stream(self.stream, **params)

    def channel_process(self) -> channel_lib.ChannelProcess:
        return channel_lib.make_channel(self.channel, **dict(self.channel_params))


class Backend(NamedTuple):
    """Model plug-in for the simulator (CNN for the paper; LMs at scale)."""

    init: Callable[[jax.Array], Any]
    grad_loss: Callable[[Any, jax.Array, jax.Array], Tuple[jax.Array, Any]]
    feature: Callable[[Any, jax.Array], jax.Array]  # (params, inputs) -> (F,)
    predict: Callable[[Any, jax.Array], jax.Array]
    feature_dim: int
    num_classes: int


class EpochCarry(NamedTuple):
    global_params: Any
    msg_params: Any  # (N, ...) stacked messages
    h: jax.Array  # (N, F) historical moments
    age: jax.Array  # (N,)
    battery: jax.Array  # (N,)
    pending: jax.Array  # (N,) bool
    counter: jax.Array  # (N,)
    key: jax.Array
    # persistent HarvestProcess state (None for per-epoch-reseeded processes
    # such as the memoryless bernoulli default — see DESIGN.md §7)
    harvest: Any = None
    # persistent DataStream state (None for the stateless "static" stream —
    # see DESIGN.md §10)
    stream: Any = None
    # lossy-uplink retry state machine (DESIGN.md §12): per-client count of
    # failed delivery attempts for the CURRENT pending message, and epochs
    # left to sit out before re-contending (capped exponential backoff).
    # Both stay all-zero under the "ideal" channel.
    retries: Any = None  # (N,) int32
    backoff: Any = None  # (N,) int32
    # persistent ChannelProcess state (None for the stateless "ideal"
    # default — see DESIGN.md §12)
    channel: Any = None


def _local_train(
    params: Any,
    images: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    cfg: EHFLConfig,
    backend: Backend,
    with_feature: bool = True,
) -> Tuple[Any, jax.Array | None]:
    """BATCHTRAIN (Alg. 1 lines 23-29): kappa minibatch SGD steps over one
    permutation pass; accumulates the Eq. (6) historical moment.

    ``with_feature=False`` drops the per-step feature forward pass and
    returns ``None`` for the moment — the Eq. 6 accumulator only exists for
    VAoI policies, and ``backend.feature`` is a pure function of the params,
    so skipping it leaves the SGD trajectory bit-identical."""
    n = images.shape[0]
    bs = max(1, n // cfg.kappa)
    perm = jax.random.permutation(key, n)[: cfg.kappa * bs].reshape(cfg.kappa, bs)

    def step(carry, idx):
        params, fsum = carry
        imgs, lbls = images[idx], labels[idx]
        _, grads = backend.grad_loss(params, imgs, lbls)
        params = sgd_update(params, grads, cfg.lr)
        if with_feature:
            f = backend.feature(params, imgs)  # batch-mean feature of w^(t,b+1)
            fsum = fsum + f * bs
        return (params, fsum), None

    fsum0 = jnp.zeros((backend.feature_dim,), jnp.float32) if with_feature else None
    (params, fsum), _ = jax.lax.scan(step, (params, fsum0), perm)
    return params, fsum / (cfg.kappa * bs) if with_feature else None


def _masked_mean(
    stacked: Any, mask: jax.Array, fallback: Any, reduce_sum: Callable | None = None
) -> Any:
    """FedAvg over the masked clients; fallback when no uploads.
    ``reduce_sum`` folds per-shard partial sums/counts into fleet totals
    (the fleet path passes a psum; default identity = full client axis)."""
    r = reduce_sum or (lambda x: x)
    cnt = r(jnp.sum(mask.astype(jnp.float32)))

    def agg(leaf, fb):
        m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        s = r(jnp.sum(leaf * m, axis=0)) / jnp.maximum(cnt, 1.0).astype(leaf.dtype)
        return jnp.where(cnt > 0, s, fb)

    return jax.tree.map(agg, stacked, fallback)


def flatten_clients(stacked: Any) -> Tuple[jax.Array, Any]:
    """Ravel a stacked (N, ...) pytree into one (N, P) matrix + structure aux
    (the layout the ``fedavg_reduce`` Pallas kernel consumes)."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    flat = jnp.concatenate([l.reshape((l.shape[0], -1)) for l in leaves], axis=1)
    return flat, (treedef, [(l.shape[1:], l.dtype) for l in leaves])


def unflatten_clients(vec: jax.Array, aux: Any) -> Any:
    """Inverse of :func:`flatten_clients` for one aggregated (P,) vector."""
    treedef, shapes = aux
    out, i = [], 0
    for shape, dtype in shapes:
        size = 1
        for d in shape:
            size *= d
        out.append(vec[i : i + size].reshape(shape).astype(dtype))
        i += size
    return jax.tree_util.tree_unflatten(treedef, out)


def _masked_mean_kernel(
    stacked: Any, mask: jax.Array, fallback: Any, reduce_sum: Callable | None = None
) -> Any:
    """:func:`_masked_mean` through the ``kernels/fedavg_reduce`` Pallas
    kernel: flatten the contrib pytree to (N, P), weighted-reduce with
    normalized mask weights, unflatten (DESIGN.md §4).  Same ``reduce_sum``
    hook as :func:`_masked_mean` (the fleet path reduces per shard and
    psums the (P,) partials)."""
    from repro.kernels import ops as kops

    r = reduce_sum or (lambda x: x)
    cnt = r(jnp.sum(mask.astype(jnp.float32)))
    w = mask.astype(jnp.float32) / jnp.maximum(cnt, 1.0)
    flat, aux = flatten_clients(stacked)
    mean = unflatten_clients(r(kops.fedavg_reduce(flat, w)), aux)
    return jax.tree.map(lambda s, fb: jnp.where(cnt > 0, s, fb), mean, fallback)


def _compact_mean(
    slab: Any,
    slab_mask: jax.Array,
    old: Any,
    old_mask: jax.Array,
    fallback: Any,
    reduce_sum: Callable | None = None,
    use_kernel: bool = False,
) -> Any:
    """FedAvg for the compacted path (DESIGN.md §11): this epoch's fresh
    uploads live in the ``(cap, ...)`` training slab (``slab_mask``), while
    ``pending_in`` carriers upload their OLD message straight from the
    N-wide ``old`` tree (``old_mask``) — their stale params were never
    re-trained, so there is nothing to gather.  The two partial sums share
    one count; the old-carrier pass is bandwidth-only (no training FLOPs).
    ``reduce_sum`` folds per-shard partials into fleet totals, exactly as in
    :func:`_masked_mean`."""
    r = reduce_sum or (lambda x: x)
    cnt = r(
        jnp.sum(slab_mask.astype(jnp.float32)) + jnp.sum(old_mask.astype(jnp.float32))
    )
    if use_kernel:
        from repro.kernels import ops as kops

        sflat, aux = flatten_clients(slab)
        oflat, _ = flatten_clients(old)
        tot = r(
            kops.fedavg_reduce(sflat, slab_mask.astype(jnp.float32))
            + kops.fedavg_reduce(oflat, old_mask.astype(jnp.float32))
        )
        mean = unflatten_clients(tot / jnp.maximum(cnt, 1.0), aux)
        return jax.tree.map(lambda s, fb: jnp.where(cnt > 0, s, fb), mean, fallback)

    def agg(s_leaf, o_leaf, fb):
        ms = slab_mask.reshape((-1,) + (1,) * (s_leaf.ndim - 1)).astype(s_leaf.dtype)
        mo = old_mask.reshape((-1,) + (1,) * (o_leaf.ndim - 1)).astype(o_leaf.dtype)
        tot = r(jnp.sum(s_leaf * ms, axis=0) + jnp.sum(o_leaf * mo, axis=0))
        s = tot / jnp.maximum(cnt, 1.0).astype(s_leaf.dtype)
        return jnp.where(cnt > 0, s, fb)

    return jax.tree.map(agg, slab, old, fallback)


def resolve_compact_cap(cfg: EHFLConfig, spec: policy_lib.PolicySpec) -> int | None:
    """The static training-slab size for this (config, policy), or ``None``
    for the dense path.  Compaction engages when the policy's per-epoch
    starter bound (``PolicySpec.max_active``) is below N — ``fedavg``
    (max_active == N) therefore always falls back dense, under "auto" AND
    under ``compact=True`` (the slab would be the whole fleet)."""
    # identity checks: `0 in (True, False, "auto")` is True (0 == False), so
    # a membership test would let falsy non-bool values slip into compaction
    if cfg.compact is False:
        return None
    if cfg.compact is not True and cfg.compact != "auto":
        raise ValueError(f"compact must be True, False or 'auto'; got {cfg.compact!r}")
    cap = spec.max_active
    if cap <= 0 or cap >= cfg.num_clients:
        return None
    return cap


def init_carry(cfg: EHFLConfig, backend: Backend, seed: jax.Array | int | None = None) -> EpochCarry:
    """Initial :class:`EpochCarry` for one simulation.  ``seed`` defaults to
    ``cfg.seed`` and may be a traced scalar (so this vmaps over seeds)."""
    N = cfg.num_clients
    key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
    k_init, k_run = jax.random.split(key)
    global_params = backend.init(k_init)
    msg_params = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (N,) + x.shape), global_params)
    process = cfg.harvest_process()
    hstate = None
    if process.persistent:
        k_run, k_harvest = jax.random.split(k_run)
        hstate = process.init(k_harvest, N)
    # stream state is split AFTER harvest state, so existing harvest-scenario
    # PRNG chains are unchanged; the stateless "static" default splits
    # nothing, keeping the seed chain bit-identical (DESIGN.md §10)
    data_stream = cfg.data_stream(backend.num_classes)
    sstate = None
    if data_stream.persistent:
        k_run, k_stream = jax.random.split(k_run)
        sstate = data_stream.init(k_stream, N)
    # channel state splits AFTER stream state (same chain-preservation rule:
    # the stateless "ideal" default splits nothing, so harvest/stream PRNG
    # chains — and the whole default trajectory — stay bit-identical)
    chan = cfg.channel_process()
    cstate = None
    if chan.persistent:
        k_run, k_chan = jax.random.split(k_run)
        cstate = chan.init(k_chan, N)
    return EpochCarry(
        global_params=global_params,
        msg_params=msg_params,
        h=jnp.zeros((N, backend.feature_dim), jnp.float32),
        age=jnp.zeros((N,), jnp.float32),
        battery=jnp.zeros((N,), jnp.int32),
        pending=jnp.zeros((N,), bool),
        counter=jnp.zeros((N,), jnp.int32),
        key=k_run,
        harvest=hstate,
        stream=sstate,
        retries=jnp.zeros((N,), jnp.int32),
        backoff=jnp.zeros((N,), jnp.int32),
        channel=cstate,
    )


class EpochOps(NamedTuple):
    """The five shard-aware points of the epoch body.  The solo defaults
    below operate on the full client axis; ``core/fleet.py`` substitutes
    distributed forms (psum/all-gather) so one :func:`epoch_body` serves
    both the single-device and the client-sharded path (DESIGN.md §9)."""

    select: Callable  # (spec, age, t, k, key) -> (N_loc,) mask
    train_keys: Callable  # (k_train, n_loc) -> (n_loc, 2) per-client keys
    masked_mean: Callable  # (contrib, mask, fallback) -> aggregated params
    reduce_sum: Callable  # (N_loc,) -> fleet-wide scalar
    # compacted FedAvg (DESIGN.md §11):
    # (slab, slab_mask, old, old_mask, fallback) -> aggregated params
    compact_mean: Callable = _compact_mean


def solo_ops(cfg: EHFLConfig, use_kernel: bool = False) -> EpochOps:
    return EpochOps(
        select=policy_lib.epoch_selection,
        train_keys=lambda k_train, n_loc: jax.random.split(k_train, cfg.num_clients),
        masked_mean=_masked_mean_kernel if use_kernel else _masked_mean,
        reduce_sum=jnp.sum,
        compact_mean=lambda slab, sm, old, om, fb: _compact_mean(
            slab, sm, old, om, fb, use_kernel=use_kernel
        ),
    )


def epoch_body(
    carry: EpochCarry,
    t: jax.Array,
    images: jax.Array,
    labels: jax.Array,
    *,
    cfg: EHFLConfig,
    backend: Backend,
    spec: policy_lib.PolicySpec,
    process: harvest_lib.HarvestProcess,
    ops: EpochOps,
    stream: stream_lib.DataStream | None = None,
    channel: channel_lib.ChannelProcess | None = None,
    use_kernel: bool = False,
) -> Tuple[EpochCarry, Dict[str, jax.Array]]:
    """One epoch of Alg. 1 over the clients in ``carry`` (all N, or one
    shard's slice when driven by ``core/fleet.py`` — ``ops`` carries the
    only four operations that differ).  ``images``/``labels`` are the
    per-client sample POOLS; ``stream`` turns them into this epoch's view
    (DESIGN.md §10; ``None`` and the "static" stream are the identity).
    ``channel`` decides which uploads actually land (DESIGN.md §12; ``None``
    and the "ideal" channel deliver everything, bit-identically)."""
    N, S, kappa = cfg.num_clients, cfg.slots_per_epoch, cfg.kappa
    n_loc = carry.age.shape[0]
    k_sel, k_scan, k_train, k_next = jax.random.split(carry.key, 4)

    # --- per-epoch data view (DataStream, DESIGN.md §10) ---
    stream_state = carry.stream
    if stream is not None:
        idx, stream_state = stream.step(stream_state, t, labels)
        images, labels = stream_lib.apply_view(idx, images, labels)
    probe_imgs = images[:, : cfg.probe_size]

    # --- CLIENTSELECT (Alg. 2) on the freshly-broadcast global model ---
    selected = ops.select(spec, carry.age, t, cfg.k, k_sel)
    if spec.uses_vaoi:
        v = jax.vmap(lambda imgs: backend.feature(carry.global_params, imgs))(probe_imgs)
        if use_kernel:  # fused Pallas kernel (Eq. 5 + Eq. 7 in one pass)
            from repro.kernels import ops as kops

            m, age = kops.vaoi_distance(
                v, carry.h, carry.age, selected.astype(jnp.float32), cfg.mu
            )
        else:
            m = vaoi_lib.feature_distance(v, carry.h)
            age = vaoi_lib.vaoi_update(carry.age, m, selected.astype(jnp.float32), cfg.mu)
    else:
        age = carry.age
        m = jnp.zeros((n_loc,), jnp.float32)

    # --- slot-level energy dynamics ---
    want_fn = policy_lib.make_want_fn(spec, selected, S, kappa)
    opp_fn = policy_lib.make_opportunity_fn(spec, selected, S, kappa)
    st0 = energy_lib.SlotState(
        battery=carry.battery,
        started=jnp.zeros((n_loc,), bool),
        start_slot=jnp.full((n_loc,), S, jnp.int32),
        pending=carry.pending,
        uploaded=jnp.zeros((n_loc,), bool),
        counter=carry.counter,
        energy_used=jnp.zeros((n_loc,), jnp.int32),
        key=k_scan,
        harvest=carry.harvest,  # None -> re-seeded from k_scan in scan_epoch
        stream=stream_state,  # rides the slot scan untouched (hook for
        # slot-granular arrival processes; per-epoch streams step above)
    )
    st = energy_lib.scan_epoch(
        st0, S=S, kappa=kappa, e_max=cfg.e_max, process=process,
        want_fn=want_fn, count_opportunity_fn=opp_fn,
        # retry backoff gates transmission for the whole epoch (the pending
        # message — and its energy — is held, not re-contended)
        tx_allowed=(carry.backoff == 0) if channel is not None else None,
    )

    # --- uplink channel + retry state machine (DESIGN.md §12) ---
    # ``st.uploaded`` clients SPENT a transmission unit; the channel decides
    # whose message landed.  A failed carrier re-queues (pending again, an
    # old-carrier retransmission once its backoff expires), re-ages its VAoI
    # by one version per failure, and is dropped after max_retries — the
    # energy is never refunded.
    upload_mask = st.uploaded
    pending_out, retries_out, backoff_out, cstate_out = (
        st.pending, carry.retries, carry.backoff, None
    )
    failed = dropped = None
    if channel is not None:
        delivered, cstate_out = channel.step(carry.channel, st.uploaded)
        failed = st.uploaded & ~delivered
        attempts = carry.retries + failed.astype(jnp.int32)
        dropped = failed & (attempts >= cfg.max_retries)
        retrying = failed & ~dropped
        # capped exponential backoff: sit out min(2^(attempts-1), cap)
        # epochs before re-contending (attempt counts are tiny, but the
        # shift is clamped so a misconfigured max_retries can't overflow)
        boff = jnp.minimum(
            jnp.left_shift(1, jnp.minimum(attempts - 1, 30)), cfg.backoff_cap
        ).astype(jnp.int32)
        upload_mask = delivered
        pending_out = st.pending | retrying
        retries_out = jnp.where(
            delivered | dropped, 0, jnp.where(retrying, attempts, carry.retries)
        )
        backoff_out = jnp.where(retrying, boff, jnp.maximum(carry.backoff - 1, 0))
        # VAoI re-aging: the scheduler must see the server's TRUE staleness —
        # a lost version is one more version the server is behind by
        age = age + failed.astype(age.dtype)
        if not channel.persistent:
            cstate_out = None

    # --- local training (only VAoI policies read the Eq. 6 moment h) ---
    pending_in = carry.pending  # entered the epoch with an unsent (old) message?
    train_keys = ops.train_keys(k_train, n_loc)
    cap = resolve_compact_cap(cfg, spec)
    train_one = lambda imgs, lbls, k: _local_train(
        carry.global_params, imgs, lbls, k, cfg, backend, with_feature=spec.uses_vaoi
    )

    if cap is None:
        # --- dense path: vmap over all clients, mask by st.started ---
        trained, h_new = jax.vmap(train_one)(images, labels, train_keys)
        started_m = st.started
        sel = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(started_m.reshape((-1,) + (1,) * (a.ndim - 1)), a, b), new, old
        )
        msg_params = sel(trained, carry.msg_params)
        h = jnp.where(started_m[:, None], h_new, carry.h) if spec.uses_vaoi else carry.h

        # aggregation (DELIVERED uploads of this epoch; old-pending uploads
        # use old msgs — a lossy channel shrinks the mask, never the msgs)
        contrib = jax.tree.map(
            lambda old, new: jnp.where(
                pending_in.reshape((-1,) + (1,) * (old.ndim - 1)), old, new
            ),
            carry.msg_params,
            msg_params,
        )
        new_global = ops.masked_mean(contrib, upload_mask, carry.global_params)
    else:
        # --- active-set compaction (DESIGN.md §11): gather the started
        # clients into a static (cap_loc, ...) slab, train only the slab,
        # scatter params/moments back.  Starters never exceed the slab —
        # they are a subset of the selection mask, whose popcount
        # ``PolicySpec.max_active`` bounds (asserted in tests/test_compact).
        cap_loc = min(cap, n_loc)
        # stable argsort of the ~started mask: started clients first, in
        # ascending client order — so slab lane j is the j-th started client
        slab_idx = jnp.argsort(~st.started)[:cap_loc]
        slab_valid = jnp.arange(cap_loc) < jnp.sum(st.started.astype(jnp.int32))
        trained, h_slab = jax.vmap(train_one)(
            images[slab_idx], labels[slab_idx], train_keys[slab_idx]
        )
        # invalid (padding) lanes scatter out of bounds -> dropped
        scat_idx = jnp.where(slab_valid, slab_idx, n_loc)
        msg_params = jax.tree.map(
            lambda mp, tr: mp.at[scat_idx].set(tr, mode="drop"), carry.msg_params, trained
        )
        h = (
            carry.h.at[scat_idx].set(h_slab, mode="drop")
            if spec.uses_vaoi
            else carry.h
        )

        # aggregation: fresh DELIVERED uploads (delivered & ~pending_in, a
        # subset of started) reduce over the slab; pending_in carriers upload
        # their OLD message from the N-wide msg tree (bandwidth-only pass).
        # The channel's delivery mask gates both passes identically to the
        # dense path, so lossy compact == lossy dense stays exact.
        slab_new = (upload_mask & ~pending_in)[slab_idx] & slab_valid
        old_mask = upload_mask & pending_in
        new_global = ops.compact_mean(
            trained, slab_new, carry.msg_params, old_mask, carry.global_params
        )

    zero = jnp.zeros((), jnp.int32)
    metrics = {
        "energy": ops.reduce_sum(st.energy_used),
        "avg_age": ops.reduce_sum(age) / N,
        "n_started": ops.reduce_sum(st.started.astype(jnp.int32)),
        "n_uploaded": ops.reduce_sum(st.uploaded.astype(jnp.int32)),
        "avg_m": ops.reduce_sum(m) / N,
        # channel outcomes: n_uploaded counts ATTEMPTS (energy spent);
        # n_delivered what landed; n_failed/n_dropped the channel's toll
        "n_delivered": ops.reduce_sum(upload_mask.astype(jnp.int32)),
        "n_failed": ops.reduce_sum(failed.astype(jnp.int32)) if failed is not None else zero,
        "n_dropped": ops.reduce_sum(dropped.astype(jnp.int32)) if dropped is not None else zero,
    }
    return (
        EpochCarry(
            global_params=new_global,
            msg_params=msg_params,
            h=h,
            age=age,
            battery=st.battery,
            pending=pending_out,
            counter=st.counter,
            key=k_next,
            harvest=st.harvest if process.persistent else None,
            stream=st.stream if stream is not None and stream.persistent else None,
            retries=retries_out,
            backoff=backoff_out,
            channel=cstate_out,
        ),
        metrics,
    )


def make_epoch_fn(
    cfg: EHFLConfig,
    backend: Backend,
    data: Dict[str, jax.Array],
    use_kernel: bool = False,
) -> Callable[[EpochCarry, jax.Array], Tuple[EpochCarry, Dict[str, jax.Array]]]:
    """One epoch of Alg. 1 as a pure ``(carry, t) -> (carry, metrics)``
    function — scan it for a solo run, vmap the scan for a seed sweep."""
    spec = policy_lib.make_policy(
        cfg.policy, num_clients=cfg.num_clients, k=cfg.k, num_groups=cfg.num_groups
    )
    process = cfg.harvest_process()
    stream = cfg.data_stream(backend.num_classes)
    chan = cfg.channel_process()
    ops = solo_ops(cfg, use_kernel)
    return lambda carry, t: epoch_body(
        carry, t, data["images"], data["labels"],
        cfg=cfg, backend=backend, spec=spec, process=process, ops=ops,
        stream=stream, channel=chan, use_kernel=use_kernel,
    )


@functools.lru_cache(maxsize=16)
def _jitted_predict(predict: Callable) -> Callable:
    """Per-``backend.predict`` jit cache: ``drive_epochs`` used to build a
    fresh ``jax.jit(lambda ...)`` wrapper per call, so every simulation
    re-traced eval; keying on the predict callable reuses the trace across
    runs (and across the eval_every chunks of one run) for a long-lived
    backend.  Bounded so freshly-built backends (each ``cnn_backend`` call
    makes a new predict closure) evict instead of pinning their closures
    and compiled executables forever."""
    return jax.jit(predict)


def drive_epochs(
    scan_chunk: Callable,
    carry: EpochCarry,
    cfg: EHFLConfig,
    backend: Backend,
    data: Dict[str, jax.Array],
) -> Dict[str, Any]:
    """The host loop shared by :func:`run_simulation` and ``fleet.run_fleet``:
    scan epochs in ``eval_every`` chunks with periodic macro-F1 eval.
    ``scan_chunk(carry, ts) -> (carry, metrics)`` hides solo vs sharded.

    ``scan_chunk`` may donate its carry argument (both callers do): the
    loop never reuses a carry after passing it in."""
    all_metrics = []
    f1s, f1_epochs = [], []
    eval_fn = _jitted_predict(backend.predict)
    from repro.models.cnn import macro_f1

    chunk = max(1, cfg.eval_every)
    t = 0
    while t < cfg.epochs:
        n = min(chunk, cfg.epochs - t)
        carry, ms = scan_chunk(carry, jnp.arange(t, t + n))
        all_metrics.append(ms)
        preds = eval_fn(carry.global_params, data["test_images"])
        f1s.append(float(macro_f1(preds, data["test_labels"], backend.num_classes)))
        f1_epochs.append(t + n)
        t += n

    metrics = {k: jnp.concatenate([m[k] for m in all_metrics]) for k in all_metrics[0]}
    metrics["f1"] = jnp.array(f1s)
    metrics["f1_epochs"] = jnp.array(f1_epochs)
    metrics["total_energy"] = jnp.sum(metrics["energy"])
    return {"metrics": metrics, "global_params": carry.global_params, "carry": carry}


def run_simulation(
    cfg: EHFLConfig,
    backend: Backend,
    data: Dict[str, jax.Array],
    use_kernel: bool = False,
) -> Dict[str, Any]:
    """Run T epochs of Alg. 1. Returns metric trajectories + final model."""
    epoch_fn = make_epoch_fn(cfg, backend, data, use_kernel=use_kernel)
    # the carry is donated: msg_params is N stacked model copies, and
    # without donation every eval_every chunk allocates a fresh copy
    scan_chunk = jax.jit(
        lambda c, ts: jax.lax.scan(epoch_fn, c, ts), donate_argnums=(0,)
    )
    return drive_epochs(scan_chunk, init_carry(cfg, backend), cfg, backend, data)


def run_batch(
    cfg: EHFLConfig,
    backend: Backend,
    data: Dict[str, jax.Array],
    seeds: Sequence[int] | jax.Array,
    use_kernel: bool = False,
) -> Dict[str, Any]:
    """Multi-seed sweep: the whole T-epoch simulation (periodic eval
    included) vmapped over a seed axis and executed as ONE jitted call.

    Seed i of the batch follows the exact same PRNG chain as
    ``run_simulation(dataclasses.replace(cfg, seed=seeds[i]), ...)`` — the
    slot-level integer dynamics are bit-identical; float trajectories agree
    up to compilation-order rounding.  ``data`` is shared across seeds (the
    standard multi-seed protocol: one partition, many scheduling runs).

    Returns the same dict shape as :func:`run_simulation` with a leading
    seed axis on every metric, ``global_params`` and ``carry`` leaf —
    except ``metrics["f1_epochs"]``, the eval schedule, which is shared
    across seeds and stays 1-D ``(n_evals,)``.
    """
    seeds = jnp.asarray(seeds, jnp.int32)
    epoch_fn = make_epoch_fn(cfg, backend, data, use_kernel=use_kernel)
    from repro.models.cnn import macro_f1

    chunk = max(1, cfg.eval_every)
    n_full, rem = divmod(cfg.epochs, chunk)

    def eval_f1(params):
        preds = backend.predict(params, data["test_images"])
        return macro_f1(preds, data["test_labels"], backend.num_classes)

    def solo(seed):
        carry = init_carry(cfg, backend, seed)
        ms_parts, f1_parts = [], []
        if n_full:
            def chunk_body(c, i):
                c, ms = jax.lax.scan(epoch_fn, c, i * chunk + jnp.arange(chunk))
                return c, (ms, eval_f1(c.global_params))

            carry, (ms, f1s) = jax.lax.scan(chunk_body, carry, jnp.arange(n_full))
            ms_parts.append(
                jax.tree.map(lambda x: x.reshape((n_full * chunk,) + x.shape[2:]), ms)
            )
            f1_parts.append(f1s)
        if rem:
            carry, ms_tail = jax.lax.scan(
                epoch_fn, carry, jnp.arange(n_full * chunk, cfg.epochs)
            )
            ms_parts.append(ms_tail)
            f1_parts.append(eval_f1(carry.global_params)[None])
        metrics = (
            jax.tree.map(lambda *xs: jnp.concatenate(xs), *ms_parts)
            if len(ms_parts) > 1
            else ms_parts[0]
        )
        metrics = dict(metrics)
        metrics["f1"] = jnp.concatenate(f1_parts) if len(f1_parts) > 1 else f1_parts[0]
        return carry, metrics

    carries, metrics = jax.jit(jax.vmap(solo))(seeds)
    metrics["f1_epochs"] = jnp.asarray(
        [chunk * (i + 1) for i in range(n_full)] + ([cfg.epochs] if rem else [])
    )
    metrics["total_energy"] = jnp.sum(metrics["energy"], axis=-1)  # (R,)
    return {"metrics": metrics, "global_params": carries.global_params, "carry": carries}
