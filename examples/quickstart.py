"""Quickstart: VAoI-scheduled EHFL vs greedy FedAvg in ~a minute on CPU,
then the harvest-scenario gallery through the seed-vmapped sweep engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.cifar_cnn import CNNConfig
from repro.core import SCENARIOS, EHFLConfig, run_batch, run_simulation
from repro.data import make_federated_dataset
from repro.fl import cnn_backend

cnn = CNNConfig(name="quick", image_size=16, conv_channels=(8, 8, 16, 16, 32, 32), fc_dims=(64, 32))
data = make_federated_dataset(
    jax.random.PRNGKey(0), num_clients=12, samples_per_client=60,
    alpha=0.1, test_size=200, image_size=16,
)
backend = cnn_backend(cnn)

print(f"{'policy':<14} {'final F1':>9} {'energy':>8} {'trainings':>10}")
for policy in ("vaoi", "fedavg", "fedbacys", "fedbacys_odd"):
    cfg = EHFLConfig(
        num_clients=12, epochs=25, slots_per_epoch=30, kappa=20, p_bc=0.3,
        k=4, mu=0.5, e_max=25, policy=policy, eval_every=25, probe_size=15, lr=0.05,
    )
    out = run_simulation(cfg, backend, data)
    m = out["metrics"]
    print(
        f"{policy:<14} {float(m['f1'][-1]):>9.4f} {float(m['total_energy']):>8.0f} "
        f"{int(m['n_started'].sum()):>10d}"
    )

# harvest-scenario gallery: same mean arrival rate, 2 seeds per scenario,
# each scenario's whole sweep is ONE jitted vmapped call (run_batch)
print(f"\n{'scenario':<11} {'final F1 (mean±std over seeds)':>31} {'energy':>8}")
for scenario in SCENARIOS:
    cfg = EHFLConfig(
        num_clients=12, epochs=10, slots_per_epoch=30, kappa=20, p_bc=0.3,
        k=4, mu=0.5, e_max=25, policy="vaoi", eval_every=10, probe_size=15,
        lr=0.05, harvest=scenario,
    )
    m = run_batch(cfg, backend, data, seeds=(0, 1))["metrics"]
    f1 = np.asarray(m["f1"])[:, -1]
    print(
        f"{scenario:<11} {f1.mean():>24.4f} ± {f1.std():.4f} "
        f"{float(np.asarray(m['total_energy']).mean()):>8.0f}"
    )
