"""Beyond-paper ablation: the significance threshold mu (Eq. 7 gate) and the
stochastic (Gumbel top-k) selection variant, at the paper's hardest cell
(alpha=0.1, p_bc=0.1)."""
from __future__ import annotations


from benchmarks.ehfl_grid import BENCH_CNN, grid_settings


def run(quick: bool = True):
    st = grid_settings(quick)
    rows = []
    # mu sweep (vaoi policy)
    import json

    import jax
    import numpy as np

    from repro.core import EHFLConfig, run_simulation
    from repro.data import make_federated_dataset
    from repro.fl import cnn_backend

    from benchmarks.ehfl_grid import CACHE

    data = make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=st["num_clients"],
        samples_per_client=st["samples"], alpha=0.1, test_size=300,
        image_size=BENCH_CNN.image_size,
    )
    backend = cnn_backend(BENCH_CNN)
    for policy, mu in [("vaoi", 0.1), ("vaoi", 0.5), ("vaoi", 2.0), ("vaoi_soft", 0.5)]:
        tag = f"abl_{policy}_mu{mu}_N{st['num_clients']}_T{st['epochs']}"
        f = CACHE / f"{tag}.json"
        if f.exists():
            rec = json.loads(f.read_text())
        else:
            cfg = EHFLConfig(
                num_clients=st["num_clients"], epochs=st["epochs"], p_bc=0.1,
                k=st["k"], mu=mu, policy=policy, alpha=0.1,
                eval_every=st["eval_every"], probe_size=20,
            )
            out = run_simulation(cfg, backend, data)
            m = out["metrics"]
            rec = {
                "f1": float(np.asarray(m["f1"])[-1]),
                "energy": float(m["total_energy"]),
                "mean_age": float(np.asarray(m["avg_age"]).mean()),
            }
            CACHE.mkdir(parents=True, exist_ok=True)
            f.write_text(json.dumps(rec))
        rows.append({
            "name": f"ablation/{policy}/mu{mu}",
            "us_per_call": 0.0,
            "derived": f"final_f1={rec['f1']:.4f};energy={rec['energy']:.0f};mean_age={rec['mean_age']:.3f}",
        })
    return rows
