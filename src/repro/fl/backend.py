"""Backends binding models to the EHFL simulator.

Contract: ``init``/``grad_loss``/``feature``/``predict`` must be pure
per-client functions of (params, batch) — the simulator vmaps them over the
stacked client axis, and the fleet path (``core/fleet.py``, DESIGN.md §9)
additionally runs them per client *shard* under ``shard_map``, where any
hidden global state or collective would break the sharded/solo equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.cifar_cnn import CNNConfig
from repro.core.simulator import Backend
from repro.models import cnn


def cnn_backend(cfg: CNNConfig) -> Backend:
    grad_loss = jax.value_and_grad(lambda p, x, y: cnn.loss_fn(cfg, p, x, y))
    return Backend(
        init=lambda key: cnn.init_params(cfg, key),
        grad_loss=grad_loss,
        feature=lambda p, x: cnn.feature_vector(cfg, p, x),
        predict=lambda p, x: cnn.predictions(cfg, p, x),
        feature_dim=cfg.num_classes,
        num_classes=cfg.num_classes,
    )


def lm_backend(model_cfg) -> Backend:
    """LM-as-client backend: tokens in, next-token loss, output-distribution
    feature tap (the paper's proxy at modern scale).  'images' = token
    sequences (N, n, S); 'labels' unused (LM loss is self-supervised)."""
    from repro.models import decoder

    def loss(p, toks, _labels):
        batch = {"tokens": toks, "labels": toks}
        l, _ = decoder.loss_fn(model_cfg, p, batch)
        return l

    grad_loss = jax.value_and_grad(loss)

    def feature(p, toks):
        return decoder.feature_vector(model_cfg, p, toks)

    def predict(p, toks):
        logits, _ = decoder.forward_logits(model_cfg, p, toks)
        return jnp.argmax(logits[:, -1], axis=-1)

    return Backend(
        init=lambda key: decoder.init_params(model_cfg, key),
        grad_loss=grad_loss,
        feature=feature,
        predict=predict,
        feature_dim=model_cfg.vocab_size,
        num_classes=model_cfg.vocab_size,
    )
