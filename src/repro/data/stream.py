"""Streaming non-IID data engine — pluggable per-epoch data views (DESIGN.md §10).

The paper's protocol (§V) freezes one Dirichlet partition for all T epochs,
so a client's local distribution never changes and the feature-based VAoI
proxy is only ever stressed by *training* dynamics, not by *data* dynamics.
Streaming FL (arXiv:2305.01238, arXiv:2405.12046) is the regime where
semantics-aware scheduling must actually earn its keep: samples arrive over
time and client distributions drift.  This module factors "what data does
client i train on at epoch t" out of the simulator behind the same tiny
stateful protocol as the harvest library (`repro.core.harvest`, DESIGN.md §7):

  * ``init(key, n) -> state``   — per-simulation stream state;
  * ``step(state, t, labels) -> (idx, state)`` — one epoch: ``idx`` is an
    ``(N, n_pool)`` int32 index map into each client's sample pool (the
    epoch's *view*), or ``None`` for the identity view.  ``labels`` is the
    per-client pool labels ``(N, n_pool)`` (weights for label-conditioned
    scenarios are computed from it at trace time).

``apply_view`` gathers the view: ``images[i, idx[i]]`` / ``labels[i, idx[i]]``.
Views always have the pool shape (``n_view == n_pool``), so every scenario
trains on exactly the same per-epoch sample budget — the streaming analogue
of the harvest gallery's mean-rate matching: compute- and energy-neutral
cross-scenario comparisons.

``persistent`` mirrors the harvest flag: ``static`` carries no state and
consumes no PRNG key, which keeps the default configuration BIT-IDENTICAL
to the frozen-partition seed behavior (tested in ``tests/test_stream.py``);
the other scenarios own a key chain threaded through ``EpochCarry.stream``.

Scenarios:

  static   — the frozen partition (identity view, the paper's protocol).
  drift    — each client's label mixture pi_i ~ Dir(alpha) rotates through
             class space with period ``period`` epochs (circularly
             interpolated, so the drift is continuous); the epoch view
             resamples the client's pool with weights pi_i(t)[label].
  arrival  — samples arrive over time: Bernoulli epochs-with-arrivals of
             mean burst size ``burst`` (mean ``rate`` samples/epoch), into
             a sliding window of the last ``window`` arrivals; the view
             wraps over the occupied window, so early training sees few
             distinct samples and redundancy is driven by the stream.
  shift    — class-incremental swaps: classes are split into
             ``num_phases`` contiguous groups and the active group swaps
             every ``period`` epochs (clients holding no active-class
             samples fall back to a uniform view of their pool).

Client-sharded forms (``make_sharded_stream``) follow the fleet recipe of
``harvest.make_sharded_process`` (DESIGN.md §9): every random draw keeps its
single-device ``(n_global, ...)`` shape, computed from the replicated key,
and each shard slices its own row window — so the fleet view is bit-identical
to the solo view and the sharded-equivalence contract extends to streams.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

SCENARIOS = ("static", "drift", "arrival", "shift")
# scenarios whose factories take a num_classes param — the simulator injects
# the backend's class count for these unless stream_params overrides it
CLASS_CONDITIONED = ("drift", "shift")


class DataStream(NamedTuple):
    """A stateful per-epoch data-view process (see module docstring)."""

    name: str
    persistent: bool  # carries state across epochs (static does not)
    init: Callable[[jax.Array, int], Any]
    step: Callable[[Any, jax.Array, jax.Array], Tuple[Optional[jax.Array], Any]]


def apply_view(idx: Optional[jax.Array], images: jax.Array, labels: jax.Array):
    """Gather the epoch view from per-client pools; ``idx=None`` = identity."""
    if idx is None:
        return images, labels
    return (
        jax.vmap(lambda im, ix: im[ix])(images, idx),
        jnp.take_along_axis(labels, idx, axis=1),
    )


# ---------------------------------------------------------------------------
# Shared machinery
# ---------------------------------------------------------------------------


def _shard_rows(full: jax.Array, _shard, n_loc: int) -> jax.Array:
    """This shard's (n_loc, ...) row window of a globally-shaped draw.
    ``_shard = (axis_name, n_global)`` under ``shard_map`` (DESIGN.md §9)."""
    axis_name, _ = _shard
    off = jax.lax.axis_index(axis_name) * n_loc
    return jax.lax.dynamic_slice_in_dim(full, off, n_loc, axis=0)


def _sample_weighted(key: jax.Array, weights: jax.Array, _shard=None) -> jax.Array:
    """With-replacement categorical view: ``idx[i, j] ~ weights[i, :]`` via
    per-client inverse-CDF over explicit uniforms (NOT ``random.categorical``,
    whose internal noise shape is an implementation detail — explicit uniforms
    make the global-draw-and-slice sharded form bit-exact by construction).
    Rows whose weights sum to ~0 fall back to a uniform view of the pool."""
    n_loc, n_pool = weights.shape
    n_glob = n_loc if _shard is None else _shard[1]
    u = jax.random.uniform(key, (n_glob, n_pool))
    if _shard is not None:
        u = _shard_rows(u, _shard, n_loc)
    tot = jnp.sum(weights, axis=1, keepdims=True)
    w = jnp.where(tot > 1e-12, weights, 1.0)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    cdf = jnp.cumsum(w, axis=1)
    idx = jax.vmap(lambda c, uu: jnp.searchsorted(c, uu, side="right"))(cdf, u)
    return jnp.minimum(idx, n_pool - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


def static(_shard=None) -> DataStream:
    """The frozen partition: identity view, no state, no PRNG consumption —
    bit-identical to the pre-stream simulator."""

    def init(key: jax.Array, n: int):
        return None

    def step(state, t: jax.Array, labels: jax.Array):
        return None, None

    return DataStream("static", False, init, step)


def rotate_mixture(pi: jax.Array, t: jax.Array, period: float) -> jax.Array:
    """Circularly rotate per-client class mixtures ``pi`` (N, C) by
    ``t * C / period`` classes, linearly interpolating fractional shifts —
    continuous drift, periodic with period ``period`` epochs."""
    C = pi.shape[1]
    s = (t % period).astype(jnp.float32) * (C / period)
    lo = jnp.floor(s).astype(jnp.int32)
    f = s - lo.astype(jnp.float32)
    cols = jnp.arange(C, dtype=jnp.int32)
    return (1.0 - f) * pi[:, (cols - lo) % C] + f * pi[:, (cols - lo - 1) % C]


def drift(
    alpha: float = 0.5, period: float = 100.0, num_classes: float = 10, _shard=None
) -> DataStream:
    """Rotating per-client Dirichlet label mixtures.  Each client draws a
    base mixture pi_i ~ Dir(alpha * 1_C) at init; at epoch t the view
    resamples its pool with weights ``rotate_mixture(pi, t, period)[label]``.
    Over one full period the time-averaged mixture is class-uniform, so the
    long-run view marginal matches the client's pool composition."""
    C = int(num_classes)
    period = max(1.0, float(period))
    a = max(1e-3, float(alpha))

    def init(key: jax.Array, n: int):
        k_pi, k_run = jax.random.split(key)
        n_draw = n if _shard is None else _shard[1]
        pi = jax.random.dirichlet(k_pi, jnp.full((C,), a), (n_draw,))
        if _shard is not None:
            pi = _shard_rows(pi, _shard, n)
        return pi.astype(jnp.float32), k_run

    def step(state, t: jax.Array, labels: jax.Array):
        pi, key = state
        k_view, k_next = jax.random.split(key)
        mix = rotate_mixture(pi, t, period)
        w = jnp.take_along_axis(mix, labels, axis=1)
        return _sample_weighted(k_view, w, _shard), (pi, k_next)

    return DataStream("drift", True, init, step)


def arrival_occupancy(count: jax.Array, window: int, n_pool: int) -> jax.Array:
    """Occupied width of the sliding window: min(arrived, window), >= 1."""
    w = n_pool if window <= 0 else min(int(window), n_pool)
    return jnp.clip(count, 1, w)


def arrival(
    rate: float = 2.0, burst: float = 1.0, window: float = 0, warm: float = 1, _shard=None
) -> DataStream:
    """Streaming sample arrivals into a sliding window.  Each epoch a burst
    arrives w.p. ``rate / b`` with mean burst size ``b = max(1, burst, rate)``
    (mean arrivals/epoch is exactly ``rate``); the client's pool is its local
    stream source in arrival order (wrapping cyclically when exhausted), and
    the view wraps over the most recent ``min(arrived, window)`` samples —
    a freshly-started client trains on very few distinct samples, so update
    redundancy is driven by the stream, not only by training.  ``warm`` (>=1)
    samples have already arrived at t=0; ``window<=0`` means the full pool."""
    rate = max(0.0, float(rate))
    b = max(1.0, float(burst), rate)
    p_burst = 0.0 if b == 0 else rate / b
    base, frac = int(b), b - int(b)
    window = int(window)
    warm = max(1, int(warm))

    def init(key: jax.Array, n: int):
        return jnp.full((n,), warm, jnp.int32), key

    def step(state, t: jax.Array, labels: jax.Array):
        count, key = state
        n_loc, n_pool = labels.shape
        n_draw = n_loc if _shard is None else _shard[1]
        k_hit, k_extra, k_next = jax.random.split(key, 3)
        hit = jax.random.bernoulli(k_hit, p_burst, (n_draw,))
        extra = jax.random.bernoulli(k_extra, frac, (n_draw,))
        if _shard is not None:
            hit = _shard_rows(hit, _shard, n_loc)
            extra = _shard_rows(extra, _shard, n_loc)
        size = base + extra.astype(jnp.int32)
        count = count + jnp.where(hit, size, 0)
        occ = arrival_occupancy(count, window, n_pool)
        j = jnp.arange(n_pool, dtype=jnp.int32)[None, :]
        idx = (count[:, None] - 1 - (j % occ[:, None])) % n_pool
        return idx.astype(jnp.int32), (count, k_next)

    return DataStream("arrival", True, init, step)


def class_group(labels: jax.Array, num_phases: int, num_classes: int) -> jax.Array:
    """Contiguous class group of each label: C classes -> P blocks."""
    return (labels * num_phases) // num_classes


def shift(
    period: float = 50.0, num_phases: float = 2, num_classes: float = 10, _shard=None
) -> DataStream:
    """Class-incremental swaps at scheduled epochs: the active class group
    ``(t // period) % num_phases`` swaps every ``period`` epochs; the view
    resamples each client's pool restricted to active-class samples (uniform
    fallback when a client holds none, via ``_sample_weighted``)."""
    period = max(1, int(period))
    P = max(1, int(num_phases))
    C = int(num_classes)

    def init(key: jax.Array, n: int):
        return key

    def step(state, t: jax.Array, labels: jax.Array):
        key = state
        k_view, k_next = jax.random.split(key)
        phase = (t.astype(jnp.int32) // period) % P
        w = (class_group(labels, P, C) == phase).astype(jnp.float32)
        return _sample_weighted(k_view, w, _shard), k_next

    return DataStream("shift", True, init, step)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FACTORIES: dict = {
    "static": static,
    "drift": drift,
    "arrival": arrival,
    "shift": shift,
}


def make_stream(name: str, **params: float) -> DataStream:
    """Build a named streaming scenario (config-side:
    ``EHFLConfig(stream="name", stream_params=(("k", v),))``)."""
    if name not in _FACTORIES:
        raise ValueError(f"unknown stream scenario {name!r}; known: {SCENARIOS}")
    return _FACTORIES[name](**params)


def state_sharding_tree(name: str):
    """Pytree matching the scenario's state structure: True where the leaf
    is per-client (shard over the fleet axis), False where replicated
    (keys).  ``static`` is stateless (None)."""
    return {
        "static": None,
        "drift": (True, False),  # (pi, key)
        "arrival": (True, False),  # (count, key)
        "shift": False,  # key
    }[name]


def make_sharded_stream(
    name: str, *, axis_name: str, n_global: int, **params: float
) -> DataStream:
    """Client-sharded counterpart of :func:`make_stream` for the fleet path
    (DESIGN.md §9/§10): ``init(key, n_loc)`` / ``step(state, t, labels_loc)``
    operate on this shard's row window under ``shard_map``, with per-client
    state (drift mixtures, arrival counters) local to the shard and keys
    replicated — and every random draw BIT-IDENTICAL to the single-device
    stream via global-draw-and-slice (asserted in ``tests/test_stream.py``)."""
    if name not in _FACTORIES:
        raise ValueError(f"unknown stream scenario {name!r}; known: {SCENARIOS}")
    return _FACTORIES[name](_shard=(axis_name, n_global), **params)
