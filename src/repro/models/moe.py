"""Mixture-of-Experts FFN: shared + routed experts, capacity-based einsum
dispatch (MaxText-style).  Experts are stacked on a leading E axis that the
launcher shards over the ``model`` mesh axis — GSPMD then emits the
all-to-all for dispatch/combine.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init, init_mlp, apply_mlp


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # router kept fp32
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) / math.sqrt(ff)).astype(dtype),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = init_mlp(ks[4], d, ff * cfg.num_shared_experts, "silu", dtype)
    return p


def apply_moe(
    cfg: ModelConfig, p: Params, x: jax.Array, group_size: int = 512
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Capacity-dropped top-k routing.

    Tokens are routed in groups of ``group_size`` along the sequence axis so
    the dispatch/combine one-hots stay O(tokens * k * G * cf) instead of
    O(tokens * k * S * cf) — essential at long sequence lengths.  Capacity is
    enforced per (batch row, group).
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    G = min(group_size, S)
    if S % G:  # fall back to one group (small/odd sequences)
        G = S
    ng = S // G
    C = max(1, int(math.ceil(k * G / E * cfg.capacity_factor)))
    C = min(C, G)

    xg = x.reshape(B, ng, G, d)
    logits = xg.astype(jnp.float32) @ p["router"]  # (B,ng,G,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (B,ng,G,k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)  # renormalize
    sel = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (B,ng,G,k,E)
    mask = jnp.sum(sel, axis=-2)  # (B,ng,G,E) in {0,1}
    gates = jnp.sum(sel * top_vals[..., None], axis=-2)  # (B,ng,G,E)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(mask, axis=2)  # (B,ng,E)
    density_proxy = jnp.mean(probs, axis=2)
    aux = jnp.mean(density * density_proxy) * (E * E) / k

    # capacity assignment within each group
    pos_in_exp = jnp.cumsum(mask, axis=2) * mask - 1.0  # (B,ng,G,E)
    keep = (pos_in_exp >= 0) & (pos_in_exp < C)
    slot = jnp.where(keep, pos_in_exp, 0).astype(jnp.int32)
    dispatch = jax.nn.one_hot(slot, C, dtype=x.dtype) * keep[..., None].astype(x.dtype)  # (B,ng,G,E,C)
    combine = dispatch * gates[..., None].astype(x.dtype)

    xe = jnp.einsum("bgtec,bgtd->begcd", dispatch, xg)  # (B,E,ng,C,d)
    h = jax.nn.silu(jnp.einsum("begcd,edf->begcf", xe, p["w_gate"]))
    h = h * jnp.einsum("begcd,edf->begcf", xe, p["w_up"])
    ye = jnp.einsum("begcf,efd->begcd", h, p["w_down"])  # (B,E,ng,C,d)
    y = jnp.einsum("bgtec,begcd->bgtd", combine, ye).reshape(B, S, d)

    if cfg.num_shared_experts > 0:
        y = y + apply_mlp(p["shared"], x, "silu")
    return y, aux
