"""Paper Fig. 4: F1 score vs epochs for every (alpha, p_bc) cell x policy.

Claim validated: the VAoI scheme wins (or ties) under severe heterogeneity
(small alpha) with scarce energy (small p_bc)."""
from __future__ import annotations

from benchmarks.ehfl_grid import POLICIES, run_grid


def run(quick: bool = True):
    cells, st = run_grid(quick)
    rows = []
    for (policy, alpha, p_bc), rec in cells.items():
        rows.append(
            {
                "name": f"fig4/{policy}/a{alpha}/p{p_bc}",
                "us_per_call": rec["wall_s"] * 1e6 / max(st["epochs"], 1),  # per epoch
                "derived": f"final_f1={rec['f1'][-1]:.4f}",
            }
        )
    # the paper's headline cell: alpha small, p_bc small -> VAoI best
    alphas = sorted({a for (_, a, _) in cells})
    pbcs = sorted({p for (_, _, p) in cells})
    a0, p0 = alphas[0], pbcs[0]
    final = {pol: cells[(pol, a0, p0)]["f1"][-1] for pol in POLICIES}
    best = max(final, key=final.get)
    rows.append(
        {
            "name": f"fig4/headline_cell_a{a0}_p{p0}",
            "us_per_call": 0.0,
            "derived": f"winner={best};" + ";".join(f"{k}={v:.4f}" for k, v in final.items()),
        }
    )
    return rows
