"""Minimal npz-based pytree checkpointing (offline container: no orbax).

Leaves are flattened to '/'-joined key paths; dtypes/shapes round-trip
exactly (bf16 is stored via a uint16 view)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def save_pytree(tree: Any, path: str | Path) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat: dict[str, np.ndarray] = {}
    meta: dict[str, str] = {}

    def record(kp, leaf):
        key = _path_str(kp)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            meta[key] = "bfloat16"
            arr = arr.view(np.uint16)
        flat[key] = arr

    jax.tree_util.tree_map_with_path(record, tree)
    np.savez(path, __meta__=json.dumps(meta), **flat)


def load_pytree(template: Any, path: str | Path) -> Any:
    data = np.load(Path(path), allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))

    def restore(kp, leaf):
        key = _path_str(kp)
        arr = data[key]
        if meta.get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        return jnp.asarray(arr).astype(leaf.dtype).reshape(leaf.shape)

    return jax.tree_util.tree_map_with_path(restore, template)
