"""End-to-end behaviour tests for the paper's system (Alg. 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_simulation
from repro.data import make_federated_dataset
from repro.fl import cnn_backend

TINY_CNN = CNNConfig(
    name="tiny", image_size=16, conv_channels=(4, 4, 8, 8, 8, 8), fc_dims=(32, 16)
)


@pytest.fixture(scope="module")
def tiny_world():
    key = jax.random.PRNGKey(0)
    data = make_federated_dataset(
        key, num_clients=8, samples_per_client=40, alpha=0.5, test_size=100, image_size=16
    )
    return data, cnn_backend(TINY_CNN)


def _cfg(**kw):
    base = dict(
        num_clients=8, epochs=8, slots_per_epoch=12, kappa=8, p_bc=0.8,
        k=3, mu=0.1, e_max=13, eval_every=4, probe_size=10,
    )
    base.update(kw)
    return EHFLConfig(**base)


@pytest.mark.parametrize("policy", ["vaoi", "fedavg", "fedbacys", "fedbacys_odd"])
def test_all_policies_run_and_learn_something(policy, tiny_world):
    data, backend = tiny_world
    out = run_simulation(_cfg(policy=policy), backend, data)
    m = out["metrics"]
    assert m["f1"].shape == (2,)
    assert np.isfinite(np.asarray(m["f1"])).all()
    assert float(m["total_energy"]) >= 0
    # energy accounting: every started training costs kappa, every upload 1
    # (so energy >= kappa * n_started)
    assert float(m["energy"].sum()) >= float(8 * m["n_started"].sum())


def test_vaoi_learns_on_tiny_problem(tiny_world):
    data, backend = tiny_world
    out = run_simulation(
        _cfg(policy="vaoi", epochs=16, eval_every=8, lr=0.05), backend, data
    )
    f1 = np.asarray(out["metrics"]["f1"])
    assert f1[-1] > 0.2  # 10-class chance is 0.1


def test_vaoi_kernel_path_matches_reference(tiny_world):
    """The Pallas vaoi_distance kernel path produces the same trajectory."""
    data, backend = tiny_world
    cfg = _cfg(policy="vaoi", epochs=4, eval_every=4)
    out_ref = run_simulation(cfg, backend, data, use_kernel=False)
    out_ker = run_simulation(cfg, backend, data, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(out_ref["metrics"]["avg_age"]),
        np.asarray(out_ker["metrics"]["avg_age"]),
        rtol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(out_ref["metrics"]["f1"]), np.asarray(out_ker["metrics"]["f1"]), rtol=1e-4, atol=1e-4
    )


def test_zero_energy_world_never_trains(tiny_world):
    data, backend = tiny_world
    out = run_simulation(_cfg(policy="vaoi", p_bc=0.0), backend, data)
    m = out["metrics"]
    assert float(m["n_started"].sum()) == 0
    assert float(m["total_energy"]) == 0
    # and the global model never moved: msg_params are initialized as a
    # broadcast of the initial global model, and nothing ever trained
    client0 = jax.tree.map(lambda x: x[0], out["carry"].msg_params)
    leaves = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), out["global_params"], client0)
    )
    assert max(leaves) == 0.0


def test_ages_reset_for_selected(tiny_world):
    data, backend = tiny_world
    out = run_simulation(_cfg(policy="vaoi", epochs=10, k=8), backend, data)
    # selecting ALL clients every epoch: ages must stay 0 forever
    assert float(out["metrics"]["avg_age"].max()) == 0.0


def test_energy_monotone_in_pbc(tiny_world):
    data, backend = tiny_world
    e = {}
    for pbc in (0.1, 0.9):
        out = run_simulation(_cfg(policy="fedavg", p_bc=pbc), backend, data)
        e[pbc] = float(out["metrics"]["total_energy"])
    assert e[0.9] >= e[0.1]


def test_lm_backend_runs_ehfl():
    """The paper's scheduler drives an assigned-architecture LM client."""
    from repro.configs import get_config, reduced
    from repro.data import make_token_dataset
    from repro.fl import lm_backend

    cfg = reduced(get_config("qwen1.5-0.5b"))
    backend = lm_backend(cfg)
    key = jax.random.PRNGKey(0)
    toks = make_token_dataset(key, 4, 24, 16, cfg.vocab_size)["tokens"]
    data = {
        "images": toks,  # simulator treats inputs generically
        "labels": jnp.zeros(toks.shape[:2], jnp.int32),
        "test_images": toks[0],
        "test_labels": jnp.zeros((toks.shape[1],), jnp.int32),
    }
    sim_cfg = EHFLConfig(
        num_clients=4, epochs=2, slots_per_epoch=8, kappa=4, p_bc=1.0,
        k=2, mu=0.01, e_max=9, eval_every=2, probe_size=4,
    )
    out = run_simulation(sim_cfg, backend, data)
    assert np.isfinite(np.asarray(out["metrics"]["avg_m"])).all()
    assert float(out["metrics"]["n_started"].sum()) > 0
