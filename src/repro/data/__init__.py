from repro.data.synthetic import (  # noqa: F401
    dirichlet_label_partition,
    make_federated_dataset,
    make_token_dataset,
)
