"""Launch-layer integration: the dry-run lowers+compiles in a subprocess
(512 placeholder devices must not leak into this test process)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


@pytest.mark.parametrize("extra", [[], ["--act-sharding", "--ce", "onehot", "--ce-chunk", "128"]])
def test_dryrun_small_seq_subprocess(tmp_path, extra):
    out = tmp_path / "rec.json"
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", "qwen1.5-0.5b", "--shape", "train_4k", "--seq", "512",
        "--out", str(out), *extra,
    ]
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=560, cwd=REPO, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec["n_chips"] == 256
    assert rec["cost"]["flops"] > 0
    assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")
    for k in ("all-gather", "all-reduce", "total"):
        assert rec["collectives"][k] >= 0


def test_jax_device_count_unpolluted():
    import jax

    assert len(jax.devices()) < 512  # dryrun's XLA_FLAGS must never leak here
