"""Lossy-uplink channel (repro/core/channel.py + the simulator's retry state
machine, DESIGN.md §12).

Contracts under test:
  * the ``ideal`` channel is the pre-channel simulator BIT-FOR-BIT (no
    state, no PRNG consumption — the trajectory equals an epoch body with
    the channel machinery removed entirely), across solo, ``run_batch``,
    and fleet drivers;
  * per-scenario delivery invariants: empirical erasure rates, ALOHA
    collision determinism (M=1 with >=2 contenders always collides, a lone
    contender always lands), fading outage in the bad link state;
  * the retry state machine: failed carriers re-queue with the capped
    exponential backoff schedule (skip min(2^(attempts-1), cap) epochs),
    drop after ``max_retries`` with no energy refund, and re-age their VAoI
    by exactly one version per failure;
  * the sharded channel (``make_sharded_channel``) is bit-identical to the
    solo channel — global-draw-and-slice, plus the psum'd ALOHA contention
    counts (rerun on 8 virtual devices by the CI multi-device leg).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_batch, run_simulation
from repro.core import channel as channel_lib
from repro.core import policies as policy_lib
from repro.core.simulator import epoch_body, init_carry, make_epoch_fn, solo_ops
from repro.data import make_federated_dataset
from repro.fl import cnn_backend
from repro.launch.mesh import make_fleet_mesh

TINY_CNN = CNNConfig(
    name="tiny", image_size=16, conv_channels=(4, 4, 8, 8, 8, 8), fc_dims=(32, 16)
)
N = 8


@pytest.fixture(scope="module")
def backend():
    return cnn_backend(TINY_CNN)


@pytest.fixture(scope="module")
def world():
    return make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=N, samples_per_client=40,
        alpha=0.5, test_size=100, image_size=16,
    )


def _cfg(**kw):
    base = dict(
        num_clients=N, epochs=4, slots_per_epoch=12, kappa=8, p_bc=0.8,
        k=3, mu=0.1, e_max=13, eval_every=4, probe_size=10,
    )
    base.update(kw)
    return EHFLConfig(**base)


def _roll(chan, attempting, steps, key=None, n=None):
    """Init + step a channel for ``steps`` epochs on a fixed attempt mask."""
    key = jax.random.PRNGKey(7) if key is None else key
    state = chan.init(key, attempting.shape[0] if n is None else n)
    outs = []
    for _ in range(steps):
        d, state = chan.step(state, attempting)
        outs.append(d)
    return jnp.stack(outs), state


# ---------------------------------------------------------------------------
# ideal: the pre-channel simulator, bit-for-bit
# ---------------------------------------------------------------------------


def test_ideal_is_stateless_and_keyless(backend):
    ch = channel_lib.make_channel("ideal")
    assert not ch.persistent
    assert ch.init(jax.random.PRNGKey(0), N) is None
    att = jnp.array([True, False, True, False])
    d, state = ch.step(None, att)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(att))
    assert state is None
    # init_carry consumes no channel key: the carry key chain equals the
    # pre-channel chain, and the retry state is born all-zero
    cfg = _cfg()
    assert cfg.channel == "ideal"  # the default IS the lossless protocol
    carry = init_carry(cfg, backend)
    _, k_run = jax.random.split(jax.random.PRNGKey(cfg.seed))
    np.testing.assert_array_equal(np.asarray(carry.key), np.asarray(k_run))
    assert carry.channel is None
    assert not np.asarray(carry.retries).any() and not np.asarray(carry.backoff).any()


def test_ideal_bitmatches_channelless_epoch_body(world, backend):
    """The full ideal-channel trajectory equals an epoch body with the
    channel machinery REMOVED (channel=None) — i.e., the pre-channel
    run_simulation path — bit for bit: metrics AND final parameters."""
    cfg = _cfg(policy="vaoi")
    epoch_fn = make_epoch_fn(cfg, backend, world)  # default channel: ideal
    spec = policy_lib.make_policy(cfg.policy, num_clients=cfg.num_clients, k=cfg.k)
    seed_fn = lambda c, t: epoch_body(
        c, t, world["images"], world["labels"],
        cfg=cfg, backend=backend, spec=spec, process=cfg.harvest_process(),
        ops=solo_ops(cfg), stream=None, channel=None,
    )
    ts = jnp.arange(cfg.epochs)
    carry_a, ms_a = jax.jit(lambda c: jax.lax.scan(epoch_fn, c, ts))(init_carry(cfg, backend))
    carry_b, ms_b = jax.jit(lambda c: jax.lax.scan(seed_fn, c, ts))(init_carry(cfg, backend))
    for k in ms_a:
        np.testing.assert_array_equal(np.asarray(ms_a[k]), np.asarray(ms_b[k]), err_msg=k)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        carry_a, carry_b,
    )
    # uploads always land under ideal
    np.testing.assert_array_equal(
        np.asarray(ms_a["n_delivered"]), np.asarray(ms_a["n_uploaded"])
    )
    assert not np.asarray(ms_a["n_failed"]).any()


# ---------------------------------------------------------------------------
# per-scenario delivery invariants
# ---------------------------------------------------------------------------


def test_erasure_empirical_loss_rate():
    n, steps, p = 512, 40, 0.3
    ch = channel_lib.make_channel("erasure", p_loss=p)
    delivered, _ = _roll(ch, jnp.ones((n,), bool), steps)
    rate = float(np.asarray(delivered).mean())
    assert abs(rate - (1.0 - p)) < 0.02
    # non-attempting clients never deliver
    att = jnp.arange(n) % 2 == 0
    delivered, _ = _roll(ch, att, 5)
    assert not np.asarray(delivered[:, 1::2]).any()


def test_erasure_hetero_rates():
    """concentration > 0 draws static per-client loss rates (mean p_loss)."""
    n = 2048
    ch = channel_lib.make_channel("erasure", p_loss=0.3, concentration=1.0)
    rates, _ = ch.init(jax.random.PRNGKey(1), n)
    rates = np.asarray(rates)
    assert abs(rates.mean() - 0.3) < 0.03
    assert rates.std() > 0.1  # genuinely heterogeneous links
    assert (rates >= 0).all() and (rates <= 1).all()


def test_aloha_collision_determinism():
    """M=1: two contenders ALWAYS collide, a lone contender ALWAYS lands."""
    ch = channel_lib.make_channel("aloha", num_channels=1)
    two = jnp.array([True, True, False, False])
    delivered, _ = _roll(ch, two, 10)
    assert not np.asarray(delivered).any()
    one = jnp.array([False, False, True, False])
    delivered, _ = _roll(ch, one, 10)
    np.testing.assert_array_equal(
        np.asarray(delivered), np.broadcast_to(np.asarray(one), (10, 4))
    )


def test_aloha_empirical_throughput():
    """All-contend delivery rate matches slotted-ALOHA theory:
    P(deliver) = (1 - 1/M)^(n-1)."""
    n, M, steps = 16, 8, 400
    ch = channel_lib.make_channel("aloha", num_channels=M)
    delivered, _ = _roll(ch, jnp.ones((n,), bool), steps)
    want = (1.0 - 1.0 / M) ** (n - 1)
    assert abs(float(np.asarray(delivered).mean()) - want) < 0.03


def test_fading_outage_extremes():
    att = jnp.ones((32,), bool)
    always_bad = channel_lib.make_channel("fading", p_bad=1.0)
    delivered, _ = _roll(always_bad, att, 8)
    assert not np.asarray(delivered).any()
    always_good = channel_lib.make_channel("fading", p_bad=0.0)
    delivered, _ = _roll(always_good, att, 8)
    assert np.asarray(delivered).all()


def test_fading_stationary_fraction():
    n, steps, pb = 256, 80, 0.4
    ch = channel_lib.make_channel("fading", p_bad=pb, sojourn=2.0)
    delivered, _ = _roll(ch, jnp.ones((n,), bool), steps)
    rate = float(np.asarray(delivered).mean())
    assert abs(rate - (1.0 - pb)) < 0.05
    # bursty: consecutive epochs of the same link state correlate
    d = np.asarray(delivered)
    agree = (d[1:] == d[:-1]).mean()
    assert agree > 0.6  # i.i.d. would sit at p^2 + (1-p)^2 = 0.52


def test_unknown_channel_raises():
    with pytest.raises(ValueError):
        channel_lib.make_channel("carrier-pigeon")
    with pytest.raises(ValueError):
        channel_lib.make_sharded_channel("x", axis_name="data", n_global=8)


# ---------------------------------------------------------------------------
# retry state machine: backoff schedule, max_retries drop, VAoI re-aging
# ---------------------------------------------------------------------------


def _epoch_stepper(cfg, backend, world, channel):
    spec = policy_lib.make_policy(cfg.policy, num_clients=cfg.num_clients, k=cfg.k)
    fn = lambda c, t: epoch_body(
        c, t, world["images"], world["labels"],
        cfg=cfg, backend=backend, spec=spec, process=cfg.harvest_process(),
        ops=solo_ops(cfg), stream=None, channel=channel,
    )
    return jax.jit(fn)


def test_backoff_schedule_and_max_retries_drop(world, backend):
    """p_loss=1: every attempt fails.  The carrier walks the capped
    exponential schedule — attempt, skip 2^(attempts-1) epochs, re-attempt —
    and is dropped (pending cleared, counters reset) after max_retries,
    with every transmission unit of energy spent and none refunded."""
    cfg = _cfg(
        policy="fedavg", p_bc=1.0, kappa=2, slots_per_epoch=8, e_max=8,
        channel="erasure", channel_params=(("p_loss", 1.0),),
        max_retries=3, backoff_cap=8,
    )
    ch = cfg.channel_process()
    step = _epoch_stepper(cfg, backend, world, ch)
    carry = init_carry(cfg, backend)
    seen = []
    for t in range(8):
        carry, ms = step(carry, jnp.asarray(t))
        seen.append({
            "uploaded": int(ms["n_uploaded"]) // N,  # homogeneous clients
            "delivered": int(ms["n_delivered"]),
            "dropped": int(ms["n_dropped"]) // N,
            "retries": int(np.asarray(carry.retries)[0]),
            "backoff": int(np.asarray(carry.backoff)[0]),
            "pending": bool(np.asarray(carry.pending)[0]),
        })
    # epoch 0: attempt 1 fails -> retries=1, skip 2^0=1 epoch
    # epoch 2: attempt 2 fails -> retries=2, skip 2^1=2 epochs
    # epoch 5: attempt 3 fails -> max_retries hit -> DROP (counters reset);
    #          having uploaded early in the epoch the client is free again
    #          (not pending, never started this epoch) and trains a FRESH
    #          update — the seed old-carrier semantics — so it ends the
    #          drop epoch pending a new message with a clean retry count
    # epoch 6: the fresh message starts its own retry ladder
    want = [
        dict(uploaded=1, retries=1, backoff=1, pending=True, dropped=0),
        dict(uploaded=0, retries=1, backoff=0, pending=True, dropped=0),
        dict(uploaded=1, retries=2, backoff=2, pending=True, dropped=0),
        dict(uploaded=0, retries=2, backoff=1, pending=True, dropped=0),
        dict(uploaded=0, retries=2, backoff=0, pending=True, dropped=0),
        dict(uploaded=1, retries=0, backoff=0, pending=True, dropped=1),
        dict(uploaded=1, retries=1, backoff=1, pending=True, dropped=0),
    ]
    for t, w in enumerate(want):
        got = {k: seen[t][k] for k in w}
        assert got == w, f"epoch {t}: {got} != {w}"
    assert all(s["delivered"] == 0 for s in seen)  # p_loss=1 delivers nothing


def test_backoff_cap_clamps_schedule(world, backend):
    """backoff_cap bounds the skip length: with cap=1 the carrier re-attempts
    every other epoch regardless of the attempt count."""
    cfg = _cfg(
        policy="fedavg", p_bc=1.0, kappa=2, slots_per_epoch=8, e_max=8,
        channel="erasure", channel_params=(("p_loss", 1.0),),
        max_retries=100, backoff_cap=1,
    )
    step = _epoch_stepper(cfg, backend, world, cfg.channel_process())
    carry = init_carry(cfg, backend)
    uploads = []
    for t in range(6):
        carry, ms = step(carry, jnp.asarray(t))
        uploads.append(int(ms["n_uploaded"]) // N)
        assert int(np.asarray(carry.backoff).max()) <= 1
    assert uploads == [1, 0, 1, 0, 1, 0]


def test_vaoi_reaging_is_exactly_one_version_per_failure(world, backend):
    """One epoch, same carry, same PRNG chain (the channel owns its own key
    chain): the lossy ages equal the ideal ages + the failed mask, the
    delivery mask gates aggregation (global model falls back), and failed
    carriers re-queue."""
    cfg = _cfg(policy="vaoi", p_bc=1.0, kappa=2, slots_per_epoch=8, e_max=8)
    lossy_cfg = dataclasses.replace(
        cfg, channel="erasure", channel_params=(("p_loss", 1.0),)
    )
    carry = init_carry(cfg, backend)  # ideal config: no channel key split
    lossy_ch = lossy_cfg.channel_process()
    carry_lossy = carry._replace(channel=lossy_ch.init(jax.random.PRNGKey(42), N))

    c_i, m_i = _epoch_stepper(cfg, backend, world, cfg.channel_process())(
        carry, jnp.asarray(0)
    )
    c_l, m_l = _epoch_stepper(lossy_cfg, backend, world, lossy_ch)(
        carry_lossy, jnp.asarray(0)
    )
    assert int(m_i["n_uploaded"]) == int(m_l["n_uploaded"]) > 0
    assert int(m_l["n_delivered"]) == 0 and int(m_l["n_failed"]) > 0
    failed = np.asarray(c_l.retries) > 0
    assert failed.sum() == int(m_l["n_failed"])
    # re-age: exactly +1 version per failed upload, bitwise elsewhere
    np.testing.assert_array_equal(
        np.asarray(c_l.age), np.asarray(c_i.age) + failed.astype(np.float32)
    )
    # nothing landed -> the global model fell back to the incoming params
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        c_l.global_params, carry.global_params,
    )
    # failed carriers hold their message for retransmission
    assert np.asarray(c_l.pending)[failed].all()


def test_lossy_mean_age_dominates_ideal(world, backend):
    """Aggregate re-aging direction: under heavy loss the fleet's mean VAoI
    sits above the lossless run's (the scheduler sees honest staleness)."""
    base = _cfg(policy="vaoi", epochs=12, eval_every=12)
    lossy = dataclasses.replace(
        base, channel="erasure", channel_params=(("p_loss", 0.8),)
    )
    age_i = float(np.asarray(run_simulation(base, backend, world)["metrics"]["avg_age"]).mean())
    age_l = float(np.asarray(run_simulation(lossy, backend, world)["metrics"]["avg_age"]).mean())
    assert age_l > age_i


# ---------------------------------------------------------------------------
# sharded == solo (global-draw-and-slice + ALOHA contention psum)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario,params", [
    ("ideal", {}),
    ("erasure", {"p_loss": 0.4, "concentration": 1.0}),
    ("aloha", {"num_channels": 2}),
    ("fading", {"p_bad": 0.4, "sojourn": 2.0}),
])
def test_sharded_channel_matches_global(scenario, params, rng):
    n, steps = 16, 6
    mesh = make_fleet_mesh(num_clients=n)
    solo = channel_lib.make_channel(scenario, **params)
    shp = channel_lib.make_sharded_channel(
        scenario, axis_name="data", n_global=n, **params
    )
    key = jax.random.PRNGKey(3)
    # a different contention pattern every step (exercises ALOHA's psum)
    atts = jax.random.bernoulli(rng, 0.6, (steps, n))

    def roll(chan, att_rows):
        state = chan.init(key, att_rows.shape[1])
        ds = []
        for i in range(steps):
            d, state = chan.step(state, att_rows[i])
            ds.append(d)
        return jnp.stack(ds)

    want = roll(solo, atts)
    got = jax.jit(
        shard_map(
            lambda a: roll(shp, a), mesh=mesh, in_specs=P(None, "data"),
            out_specs=P(None, "data"), check_rep=False,
        )
    )(atts)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got), err_msg=scenario)


# ---------------------------------------------------------------------------
# end to end through every driver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario,params", [
    ("erasure", (("p_loss", 0.5),)),
    ("aloha", (("num_channels", 1.0),)),
    ("fading", (("p_bad", 0.6), ("sojourn", 2.0))),
])
def test_lossy_end_to_end(scenario, params, world, backend):
    cfg = _cfg(policy="vaoi", channel=scenario, channel_params=params)
    m = run_simulation(cfg, backend, world)["metrics"]
    up, dl, fa = (int(np.asarray(m[k]).sum()) for k in ("n_uploaded", "n_delivered", "n_failed"))
    assert up == dl + fa and fa > 0  # the channel actually bites


def test_run_batch_matches_solo_under_loss(world, backend):
    """The seed-vmapped driver follows the same lossy chain bit-for-bit on
    the integer dynamics."""
    cfg = _cfg(policy="vaoi", channel="erasure", channel_params=(("p_loss", 0.5),))
    solo = run_simulation(cfg, backend, world)
    batch = run_batch(cfg, backend, world, seeds=[cfg.seed])
    for k in ("energy", "n_started", "n_uploaded", "n_delivered", "n_failed",
              "n_dropped", "avg_age"):
        np.testing.assert_array_equal(
            np.asarray(solo["metrics"][k]), np.asarray(batch["metrics"][k])[0],
            err_msg=k,
        )
