from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    input_specs,
    list_configs,
    reduced,
    register,
)
