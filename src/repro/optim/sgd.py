"""Optimizers: plain SGD (the paper, γ=0.01) and AdamW (at-scale training)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def sgd_update(params, grads, lr: float):
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def adamw_update(
    params,
    grads,
    state: Dict[str, Any],
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
    bc1, bc2 = 1 - b1**t, 1 - b2**t

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}
