"""The paper's own client model (§V): CNN with six convolutional layers,
three max-pooling layers, and three fully-connected layers, for CIFAR-10
(32x32x3, 10 classes)."""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CNNConfig:
    name: str = "cifar-cnn"
    family: str = "cnn"
    image_size: int = 32
    in_channels: int = 3
    num_classes: int = 10
    # six conv layers in three (conv, conv, maxpool) stages
    conv_channels: Tuple[int, ...] = (32, 32, 64, 64, 128, 128)
    fc_dims: Tuple[int, ...] = (256, 128)  # two hidden FC + final classifier = 3 FC
    source: str = "paper §V"


CONFIG = CNNConfig()


def reduced_cnn() -> CNNConfig:
    return CNNConfig(
        name="cifar-cnn-reduced",
        conv_channels=(8, 8, 16, 16, 32, 32),
        fc_dims=(64, 32),
    )
