"""Active-set compaction (simulator.epoch_body compact branch, DESIGN.md §11).

The correctness contract: with ``compact=True``/``"auto"`` the simulator
trains only the clients that actually started this epoch (gathered into a
static ``PolicySpec.max_active``-sized slab) and matches the dense path —
integer slot dynamics and VAoI ages EXACTLY, float trajectories (f1, avg_m,
params) to fp32 rounding (the slab vmap batches differently and the FedAvg
sum order differs, both last-ulp effects; macro-F1 is an argmax metric, so
its granularity sets the f1 tolerance — same contract as tests/test_fleet).

Covered drivers: solo ``run_simulation``, the seed-vmapped ``run_batch``,
and the client-sharded fleet (single-shard under tier-1; the CI multi-device
leg reruns this file under XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cifar_cnn import CNNConfig
from repro.core import EHFLConfig, run_batch, run_fleet, run_simulation
from repro.core import policies as policy_lib
from repro.core.simulator import (
    _local_train,
    epoch_body,
    init_carry,
    resolve_compact_cap,
    solo_ops,
)
from repro.data import make_federated_dataset
from repro.fl import cnn_backend

TINY_CNN = CNNConfig(
    name="tiny", image_size=16, conv_channels=(4, 4, 8, 8, 8, 8), fc_dims=(32, 16)
)
N = 16


@pytest.fixture(scope="module")
def backend():
    return cnn_backend(TINY_CNN)


@pytest.fixture(scope="module")
def world():
    return make_federated_dataset(
        jax.random.PRNGKey(0), num_clients=N, samples_per_client=40,
        alpha=0.5, test_size=100, image_size=16,
    )


def _cfg(**kw):
    base = dict(
        num_clients=N, epochs=4, slots_per_epoch=12, kappa=8, p_bc=0.6,
        k=3, mu=0.1, e_max=13, eval_every=4, probe_size=10,
    )
    base.update(kw)
    return EHFLConfig(**base)


INT_METRICS = (
    "energy", "n_started", "n_uploaded", "n_delivered", "n_failed",
    "n_dropped", "avg_age", "f1_epochs",
)
INT_CARRY = ("age", "battery", "pending", "counter", "retries", "backoff")


def _assert_equiv(dense, compact, f1_atol=0.1):
    md, mc = dense["metrics"], compact["metrics"]
    for k in INT_METRICS:
        np.testing.assert_array_equal(np.asarray(md[k]), np.asarray(mc[k]), err_msg=k)
    np.testing.assert_allclose(np.asarray(md["avg_m"]), np.asarray(mc["avg_m"]), atol=1e-3)
    np.testing.assert_allclose(np.asarray(md["f1"]), np.asarray(mc["f1"]), atol=f1_atol)
    for f in INT_CARRY:
        np.testing.assert_array_equal(
            np.asarray(getattr(dense["carry"], f)),
            np.asarray(getattr(compact["carry"], f)),
            err_msg=f"carry.{f}",
        )
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-2),
        dense["global_params"],
        compact["global_params"],
    )


# a latin square over (policy, harvest scenario, data stream, uplink
# channel): all 5 policies, a spread of harvest/stream/channel scenarios,
# each row exercising all three drivers (solo dense vs solo/batch/fleet
# compact) — lossy channels compose with compaction because old-carrier
# retransmissions ride the same pending_in fallback as seed old carriers
_CHANNEL_PARAMS = {
    "ideal": (),
    "erasure": (("p_loss", 0.4),),
    "aloha": (("num_channels", 2.0),),
    "fading": (("p_bad", 0.4), ("sojourn", 2.0)),
}


@pytest.mark.parametrize(
    "policy,scenario,stream,channel",
    [
        ("vaoi", "bernoulli", "static", "ideal"),
        ("vaoi_soft", "markov", "drift", "erasure"),
        ("fedbacys", "diurnal", "arrival", "aloha"),
        ("fedbacys_odd", "hetero", "shift", "fading"),
        ("fedavg", "bernoulli", "drift", "erasure"),  # auto-dense fallback row
    ],
)
def test_compact_matches_dense(policy, scenario, stream, channel, world, backend):
    cfg = _cfg(
        policy=policy, harvest=scenario, stream=stream,
        stream_params=(("period", 3.0),) if stream in ("drift", "shift") else (),
        channel=channel, channel_params=_CHANNEL_PARAMS[channel],
    )
    spec = policy_lib.make_policy(cfg.policy, num_clients=N, k=cfg.k)
    dense = run_simulation(dataclasses.replace(cfg, compact=False), backend, world)
    compact_cfg = dataclasses.replace(cfg, compact=True)

    # cap-saturation invariant: starters can never exceed the slab
    n_started = np.asarray(dense["metrics"]["n_started"])
    assert (n_started <= spec.max_active).all(), (policy, n_started, spec.max_active)

    solo = run_simulation(compact_cfg, backend, world)
    _assert_equiv(dense, solo)

    batch = run_batch(compact_cfg, backend, world, seeds=[cfg.seed])
    for k in INT_METRICS[:-1]:
        np.testing.assert_array_equal(
            np.asarray(dense["metrics"][k]), np.asarray(batch["metrics"][k])[0], err_msg=k
        )
    np.testing.assert_allclose(
        np.asarray(dense["metrics"]["f1"]), np.asarray(batch["metrics"]["f1"])[0], atol=0.1
    )

    fleet = run_fleet(compact_cfg, backend, world)
    _assert_equiv(dense, fleet)


def test_compact_kernel_path(world, backend):
    """use_kernel=True routes the slab AND the old-carrier partial sums
    through the fedavg_reduce Pallas kernel."""
    cfg = _cfg(policy="vaoi")
    dense = run_simulation(
        dataclasses.replace(cfg, compact=False), backend, world, use_kernel=True
    )
    compact = run_simulation(
        dataclasses.replace(cfg, compact=True), backend, world, use_kernel=True
    )
    _assert_equiv(dense, compact)


def test_cap_derivation():
    """The DESIGN.md §11 cap table: k for top-k schemes, ceil(N/G) for the
    cyclic schemes, dense fallback (None) for fedavg — under "auto" AND
    under an explicit compact=True."""
    mk = lambda pol, **kw: policy_lib.make_policy(pol, num_clients=100, k=10, **kw)
    assert mk("vaoi").max_active == 10
    assert mk("vaoi_soft").max_active == 10
    assert mk("fedbacys").max_active == 10  # G = N//k = 10 -> ceil(100/10)
    assert mk("fedbacys", num_groups=3).max_active == 34  # ceil(100/3)
    assert mk("fedbacys_odd", num_groups=7).max_active == 15
    assert mk("fedavg").max_active == 100

    cfg = EHFLConfig(num_clients=100, k=10)
    for compact in (True, "auto"):
        c = dataclasses.replace(cfg, compact=compact)
        assert resolve_compact_cap(c, mk("vaoi")) == 10
        assert resolve_compact_cap(c, mk("fedbacys", num_groups=3)) == 34
        assert resolve_compact_cap(c, mk("fedavg")) is None  # auto-dense
    off = dataclasses.replace(cfg, compact=False)
    assert resolve_compact_cap(off, mk("vaoi")) is None
    # k >= N degenerates to everyone-selected -> dense fallback too
    wide = EHFLConfig(num_clients=8, k=8)
    assert resolve_compact_cap(wide, policy_lib.make_policy("vaoi", num_clients=8, k=8)) is None
    with pytest.raises(ValueError):
        resolve_compact_cap(dataclasses.replace(cfg, compact="always"), mk("vaoi"))
    with pytest.raises(ValueError):  # falsy-but-not-False must not slip through
        resolve_compact_cap(dataclasses.replace(cfg, compact=0), mk("vaoi"))


def test_selection_popcount_never_exceeds_cap(rng):
    """The invariant the slab relies on: |epoch_selection| <= max_active for
    every policy, epoch, and key (starters are a subset of the selection)."""
    n, k = 24, 5
    age = jax.random.randint(rng, (n,), 0, 7).astype(jnp.float32)
    for policy in policy_lib.POLICIES:
        spec = policy_lib.make_policy(policy, num_clients=n, k=k)
        for t in range(6):
            mask = policy_lib.epoch_selection(
                spec, age, jnp.asarray(t), k, jax.random.fold_in(rng, 13 * t)
            )
            assert int(mask.sum()) <= spec.max_active, (policy, t)


def test_fedavg_auto_dense_is_bit_identical(world, backend):
    """fedavg under compact=True takes the dense code path, so everything —
    floats included — is bit-identical to compact=False."""
    cfg = _cfg(policy="fedavg", epochs=2, eval_every=2)
    a = run_simulation(dataclasses.replace(cfg, compact=False), backend, world)
    b = run_simulation(dataclasses.replace(cfg, compact=True), backend, world)
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        (a["metrics"], a["global_params"]),
        (b["metrics"], b["global_params"]),
    )


def test_old_carrier_uploads_bit_identical(world, backend):
    """The pending_in fallback: clients that enter the epoch with an unsent
    message and 1 battery unit upload their OLD message while nobody trains
    (p_bc=0, battery < kappa).  The compact aggregation reduces those
    carriers from the N-wide msg tree in the SAME client order as the dense
    path, so the new global is bit-identical."""
    cfg = _cfg(policy="vaoi", p_bc=0.0, epochs=1, eval_every=1)
    spec = policy_lib.make_policy(cfg.policy, num_clients=N, k=cfg.k)
    carry = init_carry(cfg, backend)
    # distinct per-client messages; clients 3, 7, 11 carry pending uploads
    msg = jax.tree.map(
        lambda x: x * (1.0 + jnp.arange(N, dtype=x.dtype).reshape((N,) + (1,) * (x.ndim - 1))),
        carry.msg_params,
    )
    pending = jnp.zeros((N,), bool).at[jnp.array([3, 7, 11])].set(True)
    carry = carry._replace(
        msg_params=msg, pending=pending, battery=pending.astype(jnp.int32)
    )

    def one_epoch(compact):
        c = dataclasses.replace(cfg, compact=compact)
        fn = lambda cc, t: epoch_body(
            cc, t, world["images"], world["labels"],
            cfg=c, backend=backend, spec=spec, process=c.harvest_process(),
            ops=solo_ops(c), stream=None,
        )
        return jax.jit(fn)(carry, jnp.asarray(0))

    (cd, md), (cc, mc) = one_epoch(False), one_epoch(True)
    assert int(md["n_uploaded"]) == 3 and int(md["n_started"]) == 0
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        (md, cd.global_params, cd.msg_params, cd.h),
        (mc, cc.global_params, cc.msg_params, cc.h),
    )


def test_local_train_feature_skip_is_free(world, backend):
    """Dropping the Eq. 6 feature accumulation (non-VAoI policies) leaves
    the SGD trajectory bit-identical and returns no moment."""
    cfg = _cfg()
    key = jax.random.PRNGKey(5)
    p0 = backend.init(jax.random.PRNGKey(1))
    imgs, lbls = world["images"][0], world["labels"][0]
    p_with, h = _local_train(p0, imgs, lbls, key, cfg, backend, with_feature=True)
    p_without, none = _local_train(p0, imgs, lbls, key, cfg, backend, with_feature=False)
    assert h.shape == (backend.feature_dim,) and none is None
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        p_with, p_without,
    )
